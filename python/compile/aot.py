"""AOT pipeline: lower every L2 entry point to HLO text + manifest.json.

This is the ONLY place python touches the artifacts the rust runtime
consumes; it runs once per `make artifacts` and never on the training path.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the published
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).  Lowering goes stablehlo -> XlaComputation with
``return_tuple=True``; the rust side unwraps the result tuple.

Usage:
    python -m compile.aot --out-dir ../artifacts [--lm-medium]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, transformer
from .shapes import DEFAULT_KRR, DEFAULT_LM, KRR_CONFIGS, LM_CONFIGS


def to_hlo_text(lowered) -> str:
    """jax Lowered -> XLA HLO text via stablehlo (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_tag(dt) -> str:
    return {"float32": "f32", "int32": "i32", "uint32": "u32"}[jnp.dtype(dt).name]


class Builder:
    """Collects lowered artifacts + their manifest entries."""

    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries: dict[str, dict] = {}
        os.makedirs(out_dir, exist_ok=True)

    def add(self, name: str, fn, arg_specs, meta: dict | None = None):
        """Lower ``fn`` at ``arg_specs`` and record inputs/outputs."""
        specs = [s for _, s in arg_specs]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(self.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)

        out_shapes = jax.eval_shape(fn, *specs)
        outputs = [
            {"shape": list(s.shape), "dtype": _dtype_tag(s.dtype)}
            for s in jax.tree_util.tree_leaves(out_shapes)
        ]
        self.entries[name] = {
            "file": fname,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            "inputs": [
                {"name": n, "shape": list(s.shape), "dtype": _dtype_tag(s.dtype)}
                for n, s in arg_specs
            ],
            "outputs": outputs,
            "meta": meta or {},
        }
        print(f"  {name:40s} {len(text):>9d} chars  "
              f"{len(arg_specs)} in / {len(outputs)} out")

    def finish(self):
        manifest = {
            "format_version": 1,
            "jax_version": jax.__version__,
            "artifacts": self.entries,
        }
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)
        print(f"wrote {path} ({len(self.entries)} artifacts)")


def f32(*shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def build_krr(b: Builder, names) -> None:
    for cname in names:
        c = KRR_CONFIGS[cname]
        l, zeta, d = c.l, c.zeta, c.d
        meta = {"config": cname, "d": d, "l": l, "zeta": zeta}
        sfx = f"_{cname}"

        b.add(f"krr_worker_grad{sfx}", model.worker_grad,
              [("theta", f32(l)), ("phi", f32(zeta, l)), ("y", f32(zeta)),
               ("lam", f32())], meta)
        b.add(f"krr_worker_grad_ref{sfx}", model.worker_grad_ref,
              [("theta", f32(l)), ("phi", f32(zeta, l)), ("y", f32(zeta)),
               ("lam", f32())], meta)
        b.add(f"krr_worker_grad_loss{sfx}", model.worker_grad_loss,
              [("theta", f32(l)), ("phi", f32(zeta, l)), ("y", f32(zeta)),
               ("lam", f32())], meta)
        b.add(f"krr_full_loss{sfx}", model.full_loss,
              [("theta", f32(l)), ("phi", f32(zeta, l)), ("y", f32(zeta)),
               ("lam", f32())], meta)
        b.add(f"krr_predict{sfx}", model.predict,
              [("theta", f32(l)), ("phi", f32(zeta, l))], meta)
        b.add(f"rbf_features{sfx}", model.features,
              [("x", f32(zeta, d)), ("w", f32(d, l)), ("b", f32(l))], meta)
        b.add(f"master_update_sgd{sfx}", model.master_update_sgd,
              [("theta", f32(l)), ("gsum", f32(l)),
               ("eta_over_gamma", f32())], meta)
        b.add(f"master_update_momentum{sfx}", model.master_update_momentum,
              [("theta", f32(l)), ("vel", f32(l)), ("gbar", f32(l)),
               ("eta", f32()), ("mu", f32())], meta)
        b.add(f"master_update_adam{sfx}", model.master_update_adam,
              [("theta", f32(l)), ("m", f32(l)), ("v", f32(l)),
               ("gbar", f32(l)), ("eta", f32()), ("beta1", f32()),
               ("beta2", f32()), ("eps", f32()), ("t", f32())], meta)


def build_lm(b: Builder, names) -> None:
    for cname in names:
        c = LM_CONFIGS[cname]
        specs = transformer.param_specs(c)
        toks = jax.ShapeDtypeStruct((c.batch, c.seq + 1), jnp.int32)
        args = [("tokens", toks)] + [
            (n, jax.ShapeDtypeStruct(s, jnp.float32)) for n, s in specs
        ]
        meta = {
            "config": cname, "vocab": c.vocab, "d_model": c.d_model,
            "n_head": c.n_head, "n_layer": c.n_layer, "seq": c.seq,
            "batch": c.batch, "d_ff": c.ff, "n_params": c.n_params(),
            "param_names": [n for n, _ in specs],
        }
        b.add(f"lm_step_{cname}", transformer.lm_step(c), args, meta)
        b.add(f"lm_loss_{cname}", transformer.lm_loss(c), args, meta)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    # Back-compat with `--out <manifest-or-hlo path>`: derive the directory.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--lm-medium", action="store_true",
                    help="also lower the ~19M-param LM (slow)")
    args = ap.parse_args()
    out_dir = args.out_dir if args.out is None else (os.path.dirname(args.out) or ".")

    b = Builder(out_dir)
    print("== KRR artifacts ==")
    build_krr(b, DEFAULT_KRR)
    print("== LM artifacts ==")
    lm = list(DEFAULT_LM) + (["lm_medium"] if args.lm_medium else [])
    build_lm(b, lm)
    b.finish()


if __name__ == "__main__":
    main()
