//! T3 — Algorithm-1 estimator validation (Lemmas 3.1/3.2).
//!
//! Three parts:
//!  (a) the finite-population-correction variance formula of Lemma 3.1,
//!      checked against Monte-Carlo resampling;
//!  (b) Lemma 3.2 coverage on a scalar population where its assumptions
//!      hold exactly (known s², Δ = ξ·|Z̄|): coverage ≈ 1−α;
//!  (c) the paper's distribution-free Algorithm-1 bound applied to real
//!      KRR *gradients*, vs the variance-aware adaptive estimator —
//!      exposing where the worst-case step (s² vs (ξZ̄)², §3.2) is and
//!      isn't conservative.
//!
//! Each part's Monte-Carlo cells run concurrently on the sweep engine
//! (`--threads N` overrides the pool size); every cell owns an
//! index-derived RNG stream, so the tables are deterministic regardless
//! of the pool size.

use hybriditer::bench_harness::sweep::SweepEngine;
use hybriditer::bench_harness::{f, Table};
use hybriditer::coordinator::estimator::{
    estimate_gamma, AdaptiveEstimator, EstimatorParams,
};
use hybriditer::data::{ComputePool, KrrProblemSpec};
use hybriditer::math::vec_ops;
use hybriditer::util::rng::Pcg64;

fn part_a_fpc(engine: &SweepEngine) {
    let mut rng = Pcg64::seeded(1);
    let n_pop = 5000usize;
    let pop: Vec<f64> = (0..n_pop).map(|_| rng.normal() * 2.0 + 1.0).collect();
    let pop_mean = pop.iter().sum::<f64>() / n_pop as f64;
    let pop_var = pop.iter().map(|x| (x - pop_mean).powi(2)).sum::<f64>() / n_pop as f64;

    let mut table = Table::new(
        "T3a Lemma 3.1: Var(sample mean) with finite-population correction",
        &["n", "predicted_var", "measured_var", "ratio"],
    );
    let ns = [10usize, 100, 1000, 4000];
    let rows = engine.run(&ns, |_, &n| {
        let mut rng = Pcg64::new(0xA3, n as u64);
        let predicted = pop_var / n as f64 * (n_pop - n) as f64 / (n_pop - 1) as f64;
        let trials = 4000;
        let mut means = Vec::with_capacity(trials);
        for _ in 0..trials {
            let idx = rng.sample_indices(n_pop, n);
            means.push(idx.iter().map(|&i| pop[i]).sum::<f64>() / n as f64);
        }
        let mm = means.iter().sum::<f64>() / trials as f64;
        let mv = means.iter().map(|x| (x - mm).powi(2)).sum::<f64>() / trials as f64;
        (predicted, mv)
    });
    for (&n, &(predicted, mv)) in ns.iter().zip(&rows) {
        table.row(vec![
            n.to_string(),
            format!("{predicted:.5e}"),
            format!("{mv:.5e}"),
            f(mv / predicted, 3),
        ]);
    }
    table.print();
    table.save_csv("t3a_fpc_variance").unwrap();
}

fn part_b_coverage(engine: &SweepEngine) {
    let mut rng = Pcg64::seeded(2);
    let n_pop = 30_000usize;
    let pop: Vec<f64> = (0..n_pop).map(|_| 4.0 + rng.normal()).collect();
    let pop_mean = pop.iter().sum::<f64>() / n_pop as f64;
    let s2 = pop.iter().map(|x| (x - pop_mean).powi(2)).sum::<f64>() / (n_pop - 1) as f64;

    let mut table = Table::new(
        "T3b Lemma 3.2 coverage on a population satisfying its assumptions",
        &["alpha", "xi", "n_lemma", "coverage_%", "target_%"],
    );
    let mut cells: Vec<(f64, f64)> = Vec::new();
    for &alpha in &[0.01, 0.05, 0.10] {
        for &xi in &[0.01, 0.02, 0.05] {
            cells.push((alpha, xi));
        }
    }
    let rows = engine.run(&cells, |_, &(alpha, xi)| {
        let mut rng = Pcg64::new(0xB3, ((alpha * 1e4) as u64) ^ (((xi * 1e6) as u64) << 16));
        let p = EstimatorParams { alpha, xi };
        let u = p.u_half_alpha();
        let delta = xi * pop_mean.abs();
        let n = ((n_pop as f64) * u * u * s2
            / (delta * delta * n_pop as f64 + u * u * s2))
            .ceil() as usize;
        let trials = 1500;
        let mut hits = 0;
        for _ in 0..trials {
            let idx = rng.sample_indices(n_pop, n);
            let mean = idx.iter().map(|&i| pop[i]).sum::<f64>() / n as f64;
            if (mean - pop_mean).abs() < delta {
                hits += 1;
            }
        }
        (n, 100.0 * hits as f64 / trials as f64)
    });
    for (&(alpha, xi), &(n, coverage)) in cells.iter().zip(&rows) {
        table.row(vec![
            f(alpha, 2),
            f(xi, 2),
            n.to_string(),
            f(coverage, 1),
            f(100.0 * (1.0 - alpha), 1),
        ]);
    }
    table.print();
    table.save_csv("t3b_lemma32_coverage").unwrap();
}

fn part_c_gradients(engine: &SweepEngine) {
    let spec = KrrProblemSpec::default_config().with_machines(32);
    let problem = engine.cache().get(&spec);
    let (n, zeta, m) = (spec.total_examples(), spec.zeta, spec.machines);
    let mut pool = problem.native_pool();

    let mut rng = Pcg64::seeded(3);
    let mut theta = vec![0.0f32; problem.dim()];
    rng.fill_normal(&mut theta, 0.0, 1.0);
    let grads: Vec<Vec<f32>> = (0..m)
        .map(|w| pool.grad(w, &theta, 0).unwrap().grad)
        .collect();
    let mut full = vec![0.0f32; problem.dim()];
    for g in &grads {
        vec_ops::add_assign(&mut full, g);
    }
    vec_ops::scale(&mut full, 1.0 / m as f32);
    let full_norm = vec_ops::norm2(&full);

    let mut table = Table::new(
        "T3c Algorithm-1 (distribution-free) vs variance-aware gamma on real gradients",
        &["alpha", "xi", "g_alg1", "cov_alg1_%", "g_adaptive", "cov_adapt_%"],
    );
    let mut cells: Vec<(f64, f64)> = Vec::new();
    for &alpha in &[0.05, 0.10] {
        for &xi in &[0.05, 0.10, 0.25] {
            cells.push((alpha, xi));
        }
    }
    let dim = problem.dim();
    let rows = engine.run(&cells, |_, &(alpha, xi)| {
        let mut rng = Pcg64::new(0xC3, ((alpha * 1e4) as u64) ^ (((xi * 1e6) as u64) << 16));
        let p = EstimatorParams { alpha, xi };
        let g1 = estimate_gamma(n, zeta, m, p).unwrap();

        let mut adaptive = AdaptiveEstimator::new(n, zeta, m, p);
        let views: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        adaptive.observe(&views);
        adaptive.observe(&views);
        let g2 = adaptive.gamma().unwrap();

        let mut coverage = |gamma: usize| {
            let trials = 400;
            let mut hits = 0;
            let mut sub = vec![0.0f32; dim];
            for _ in 0..trials {
                let idx = rng.sample_indices(m, gamma);
                sub.fill(0.0);
                for &w in &idx {
                    vec_ops::add_assign(&mut sub, &grads[w]);
                }
                vec_ops::scale(&mut sub, 1.0 / gamma as f32);
                if vec_ops::dist2(&sub, &full) / full_norm <= xi {
                    hits += 1;
                }
            }
            100.0 * hits as f64 / trials as f64
        };
        let c1 = coverage(g1);
        let c2 = coverage(g2);
        (g1, c1, g2, c2)
    });
    for (&(alpha, xi), &(g1, c1, g2, c2)) in cells.iter().zip(&rows) {
        table.row(vec![
            f(alpha, 2),
            f(xi, 2),
            g1.to_string(),
            f(c1, 1),
            g2.to_string(),
            f(c2, 1),
        ]);
    }
    table.print();
    table.save_csv("t3c_estimator_on_gradients").unwrap();
    println!(
        "\nReading: Lemma 3.2 holds exactly on populations satisfying its\n\
         assumptions (T3b ≈ target).  On real gradients the paper's final\n\
         distribution-free step (dropping s² against (ξZ̄)²) is NOT always\n\
         conservative — per-coordinate scatter can exceed the mean gradient\n\
         magnitude, so Algorithm 1 under-provisions γ where the variance-\n\
         aware (adaptive) estimator provisions correctly.  This matches the\n\
         paper's soundness assessment and motivates the DESIGN.md §6\n\
         adaptive-γ ablation."
    );
}

fn main() {
    let engine = SweepEngine::from_env();
    println!("T3: estimator validation (Lemmas 3.1, 3.2, Algorithm 1)");
    println!("sweep pool: {} threads\n", engine.threads());
    part_a_fpc(&engine);
    part_b_coverage(&engine);
    part_c_gradients(&engine);
}
