//! F3 (topology) — time-to-loss vs worker count for the three aggregation
//! overlays (star / tree / ring) under identical hop costs.
//!
//! The cost model makes the trade explicit.  A star root folds one
//! message per included reply, so its incast term grows linearly with γ
//! (≈ ¾M here); a tree folds at interior relays and hands the root
//! ≈ fan-in combined messages, paying instead with subtree-granularity
//! admission (one straggler delays its whole combined message); a ring
//! runs a collective over *all* delivered workers (γ cannot shed
//! stragglers at all) but attaches to the root as a single message.
//! Expected shape: star wins small clusters, tree overtakes once the
//! incast term outgrows the subtree-max penalty — the crossover the
//! headline reports — and ring pays the max-order statistic throughout.
//!
//! The lossy half re-runs three cluster sizes with 5% message loss:
//! interior-edge drops kill whole folded subtrees (tree) or θ segments
//! (ring), so delivered-contribution counts and final loss degrade in a
//! topology-dependent way the JSON records.
//!
//! Emits `results/BENCH_f3_topology.json`; CI uploads it and gates on
//! `tree_vs_star_ratio_at_1024` (scripts side, >20% regression fails).

use hybriditer::agg::AggSpec;
use hybriditer::bench_harness::sweep::SweepEngine;
use hybriditer::bench_harness::{f, Table};
use hybriditer::cluster::ClusterSpec;
use hybriditer::coordinator::{LossForm, RunConfig, SyncMode};
use hybriditer::data::KrrProblemSpec;
use hybriditer::net::{LinkModel, NetSpec};
use hybriditer::optim::OptimizerKind;
use hybriditer::sim::{self, NoEval};
use hybriditer::straggler::DelayModel;

const ITERS: u64 = 40;
const FOLD_COST: f64 = 2e-4;
const XFER_COST: f64 = 1e-5;
const DROP_PROB: f64 = 0.05;

/// (total virtual seconds, final recorded train loss, leaf contributions
/// lost to interior-edge drops).
fn run_one(
    problem: &hybriditer::data::KrrProblem,
    m: usize,
    agg: AggSpec,
    net: NetSpec,
) -> (f64, f64, u64) {
    let cluster = ClusterSpec {
        workers: m,
        base_compute: 0.01,
        delay: DelayModel::LogNormal { mu: -4.0, sigma: 0.5 },
        seed: 11,
        ..ClusterSpec::default()
    }
    .with_net(net)
    .with_agg(agg);
    let gamma = (m * 3 / 4).max(1);
    let cfg = RunConfig {
        mode: SyncMode::Hybrid { gamma },
        optimizer: OptimizerKind::sgd(0.8),
        loss_form: LossForm::krr(problem.spec.lambda),
        eval_every: 0,
        record_every: 1,
        ..RunConfig::default()
    }
    .with_iters(ITERS);
    let mut pool = problem.native_pool();
    let rep = sim::run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();
    assert!(rep.status.is_healthy(), "M={m} {}: {:?}", rep.mode_name, rep.status);
    let loss = rep.recorder.rows().last().map(|r| r.loss).unwrap_or(f64::NAN);
    (rep.total_time(), loss, rep.agg.lost_contributions)
}

fn spec_for(m: usize) -> KrrProblemSpec {
    // Thousands of *virtual* workers: the problem only needs one shard
    // per machine, so ζ scales with M while l stays small.
    KrrProblemSpec {
        machines: m,
        zeta: (2 * m).max(256),
        ..KrrProblemSpec::small()
    }
}

fn main() {
    let engine = SweepEngine::from_env();
    println!(
        "F3 topology: time-to-loss vs M for star/tree/ring \
         (fold {FOLD_COST}, xfer {XFER_COST}, {ITERS} iters)"
    );
    println!("sweep pool: {} threads\n", engine.threads());
    let costs = |spec: AggSpec| spec.with_costs(FOLD_COST, XFER_COST);

    // --- ideal links: the pure cost-model crossover ----------------------
    let ms = [8usize, 16, 32, 64, 256, 1024, 2048];
    let ideal = engine.run(&ms, |cache, &m| {
        let problem = cache.get(&spec_for(m));
        let star = run_one(&problem, m, costs(AggSpec::star()), NetSpec::ideal());
        let tree = run_one(&problem, m, costs(AggSpec::tree(8)), NetSpec::ideal());
        let ring = run_one(&problem, m, costs(AggSpec::ring()), NetSpec::ideal());
        (star, tree, ring)
    });

    let mut table = Table::new(
        "F3 topology: total virtual time (s) for 40 iterations, ideal links",
        &["M", "star_s", "tree_s", "ring_s", "tree/star", "ring/star"],
    );
    let mut crossover: Option<usize> = None;
    let mut ratio_at_1024 = f64::NAN;
    let mut ring_ratio_at_1024 = f64::NAN;
    for (&m, ((star_s, _, _), (tree_s, _, _), (ring_s, _, _))) in ms.iter().zip(&ideal) {
        let tree_ratio = tree_s / star_s;
        let ring_ratio = ring_s / star_s;
        if crossover.is_none() && tree_ratio < 1.0 {
            crossover = Some(m);
        }
        if m == 1024 {
            ratio_at_1024 = tree_ratio;
            ring_ratio_at_1024 = ring_ratio;
        }
        table.row(vec![
            m.to_string(),
            f(*star_s, 3),
            f(*tree_s, 3),
            f(*ring_s, 3),
            f(tree_ratio, 3),
            f(ring_ratio, 3),
        ]);
    }
    table.print();

    // --- lossy links: interior drops cost contributions ------------------
    let lossy_ms = [32usize, 128, 256];
    let lossy_net = NetSpec {
        default_link: LinkModel { drop_prob: DROP_PROB, ..LinkModel::ideal() },
        ..NetSpec::ideal()
    };
    let lossy = engine.run(&lossy_ms, |cache, &m| {
        let problem = cache.get(&spec_for(m));
        let star = run_one(&problem, m, costs(AggSpec::star()), lossy_net.clone());
        let tree = run_one(&problem, m, costs(AggSpec::tree(8)), lossy_net.clone());
        let ring = run_one(&problem, m, costs(AggSpec::ring()), lossy_net.clone());
        (star, tree, ring)
    });
    let mut ltable = Table::new(
        "F3 topology: 5% loss — time (s), final loss, and killed contributions",
        &["M", "star_s", "tree_s", "ring_s", "tree_killed", "ring_killed"],
    );
    for (&m, ((star_s, _, _), (tree_s, _, tk), (ring_s, _, rk))) in lossy_ms.iter().zip(&lossy) {
        ltable.row(vec![
            m.to_string(),
            f(*star_s, 3),
            f(*tree_s, 3),
            f(*ring_s, 3),
            tk.to_string(),
            rk.to_string(),
        ]);
    }
    ltable.print();

    // --- machine-readable trajectory point -------------------------------
    let ideal_rows: Vec<String> = ms
        .iter()
        .zip(&ideal)
        .map(|(&m, ((ss, sl, _), (ts, tl, _), (rs, rl, _)))| {
            format!(
                "    {{\"m\": {m}, \"gamma\": {}, \"star_s\": {ss:.6}, \"tree_s\": {ts:.6}, \
                 \"ring_s\": {rs:.6}, \"star_loss\": {sl:.6e}, \"tree_loss\": {tl:.6e}, \
                 \"ring_loss\": {rl:.6e}}}",
                (m * 3 / 4).max(1)
            )
        })
        .collect();
    let lossy_rows: Vec<String> = lossy_ms
        .iter()
        .zip(&lossy)
        .map(|(&m, ((ss, sl, _), (ts, tl, tk), (rs, rl, rk)))| {
            format!(
                "    {{\"m\": {m}, \"drop_prob\": {DROP_PROB}, \"star_s\": {ss:.6}, \
                 \"tree_s\": {ts:.6}, \"ring_s\": {rs:.6}, \"star_loss\": {sl:.6e}, \
                 \"tree_loss\": {tl:.6e}, \"ring_loss\": {rl:.6e}, \
                 \"tree_killed\": {tk}, \"ring_killed\": {rk}}}"
            )
        })
        .collect();
    let crossover_json =
        crossover.map(|m| m.to_string()).unwrap_or_else(|| "null".to_string());
    let json = format!(
        "{{\n  \"bench\": \"f3_topology\",\n  \"iters\": {ITERS},\n  \
         \"fold_cost\": {FOLD_COST},\n  \"xfer_cost\": {XFER_COST},\n  \"headline\": {{\n    \
         \"max_workers\": {},\n    \"crossover_workers\": {crossover_json},\n    \
         \"tree_vs_star_ratio_at_1024\": {ratio_at_1024:.4},\n    \
         \"ring_vs_star_ratio_at_1024\": {ring_ratio_at_1024:.4}\n  }},\n  \
         \"ideal\": [\n{}\n  ],\n  \"lossy\": [\n{}\n  ]\n}}\n",
        ms.iter().max().unwrap(),
        ideal_rows.join(",\n"),
        lossy_rows.join(",\n")
    );
    std::fs::create_dir_all("results").unwrap();
    std::fs::write("results/BENCH_f3_topology.json", json).unwrap();
    match crossover {
        Some(m) => println!(
            "\nheadline: tree overtakes star at M = {m}; tree/star at M=1024 = {ratio_at_1024:.3}"
        ),
        None => println!("\nheadline: no tree/star crossover up to M = 2048"),
    }
    println!("trajectory point -> results/BENCH_f3_topology.json");
}
