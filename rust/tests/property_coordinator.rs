//! Property tests on coordinator invariants (own helper — proptest is not
//! in the offline vendor set; see DESIGN.md §3).
//!
//! Each property runs over hundreds of seeded random cases; failures print
//! the seed/stream needed to replay deterministically.

use hybriditer::cluster::ClusterSpec;
use hybriditer::coordinator::aggregator::{aggregate, AggregatorKind, Contribution};
use hybriditer::coordinator::barrier::{Admission, PartialBarrier};
use hybriditer::coordinator::estimator::{estimate_gamma, estimate_sample_size, EstimatorParams};
use hybriditer::coordinator::{LossForm, RunConfig, SyncMode};
use hybriditer::data::{KrrProblem, KrrProblemSpec};
use hybriditer::optim::OptimizerKind;
use hybriditer::sim::{self, NoEval};
use hybriditer::straggler::DelayModel;
use hybriditer::util::proptest::{check, check_sized};
use hybriditer::util::rng::Pcg64;

// ---------------------------------------------------------------------
// Barrier invariants
// ---------------------------------------------------------------------

#[test]
fn prop_barrier_includes_exactly_gamma_of_any_arrival_order() {
    check("barrier_gamma_exact", 300, |rng| {
        let workers = 2 + rng.below(30) as usize;
        let gamma = 1 + rng.below(workers as u64) as usize;
        let mut order: Vec<usize> = (0..workers).collect();
        rng.shuffle(&mut order);

        let mut b = PartialBarrier::new(0, workers, gamma);
        let mut included = 0;
        let mut abandoned = 0;
        for &w in &order {
            match b.offer(w, 0) {
                Admission::Included | Admission::IncludedAndClosed => included += 1,
                Admission::Abandoned => abandoned += 1,
                Admission::Stale => return Err("unexpected stale".into()),
            }
        }
        if included != gamma {
            return Err(format!("included {included}, want {gamma}"));
        }
        if abandoned != workers - gamma {
            return Err(format!("abandoned {abandoned}, want {}", workers - gamma));
        }
        if !b.is_closed() {
            return Err("barrier not closed after all arrivals".into());
        }
        Ok(())
    });
}

#[test]
fn prop_barrier_first_gamma_by_arrival_are_the_included() {
    check("barrier_first_gamma", 200, |rng| {
        let workers = 3 + rng.below(20) as usize;
        let gamma = 1 + rng.below(workers as u64) as usize;
        let mut order: Vec<usize> = (0..workers).collect();
        rng.shuffle(&mut order);
        let mut b = PartialBarrier::new(7, workers, gamma);
        for (pos, &w) in order.iter().enumerate() {
            let adm = b.offer(w, 7);
            let should_include = pos < gamma;
            let included = matches!(
                adm,
                Admission::Included | Admission::IncludedAndClosed
            );
            if included != should_include {
                return Err(format!(
                    "arrival #{pos} (worker {w}): admission {adm:?}, expected include={should_include}"
                ));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Aggregator invariants
// ---------------------------------------------------------------------

#[test]
fn prop_mean_aggregation_bounded_by_extremes() {
    check_sized("aggregate_mean_bounds", 200, 1, 12, |k, rng| {
        let dim = 1 + rng.below(16) as usize;
        let grads: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
            .collect();
        let contribs: Vec<Contribution<'_>> = grads
            .iter()
            .map(|g| Contribution::whole(g, 1, 0))
            .collect();
        let mut out = vec![0.0f32; dim];
        aggregate(AggregatorKind::Mean, &contribs, &mut out);
        for d in 0..dim {
            let lo = grads.iter().map(|g| g[d]).fold(f32::INFINITY, f32::min);
            let hi = grads.iter().map(|g| g[d]).fold(f32::NEG_INFINITY, f32::max);
            if out[d] < lo - 1e-5 || out[d] > hi + 1e-5 {
                return Err(format!("coord {d}: mean {} outside [{lo}, {hi}]", out[d]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_weighted_equals_mean_for_equal_weights() {
    check("aggregate_weighted_eq_mean", 150, |rng| {
        let k = 2 + rng.below(6) as usize;
        let dim = 1 + rng.below(8) as usize;
        let grads: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
            .collect();
        let contribs: Vec<Contribution<'_>> = grads
            .iter()
            .map(|g| Contribution::whole(g, 64, 0))
            .collect();
        let mut a = vec![0.0f32; dim];
        let mut b = vec![0.0f32; dim];
        aggregate(AggregatorKind::Mean, &contribs, &mut a);
        aggregate(AggregatorKind::ExampleWeighted, &contribs, &mut b);
        for (x, y) in a.iter().zip(&b) {
            if (x - y).abs() > 1e-5 {
                return Err(format!("{x} vs {y}"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Estimator (Algorithm 1) invariants
// ---------------------------------------------------------------------

#[test]
fn prop_estimator_monotonicity_and_bounds() {
    check("estimator_monotone", 300, |rng| {
        let n_total = 1000 + rng.below(10_000_000) as usize;
        let zeta = 1 + rng.below(10_000) as usize;
        let m = 1 + rng.below(256) as usize;
        let alpha = rng.uniform(0.001, 0.3);
        let xi = rng.uniform(0.001, 0.5);
        let p = EstimatorParams { alpha, xi };

        let n = estimate_sample_size(n_total, p).map_err(|e| e.to_string())?;
        if !(n > 0.0 && n <= n_total as f64) {
            return Err(format!("n={n} outside (0, {n_total}]"));
        }
        let g = estimate_gamma(n_total, zeta, m, p).map_err(|e| e.to_string())?;
        if !(1..=m).contains(&g) {
            return Err(format!("gamma={g} outside [1, {m}]"));
        }
        // Monotone: stricter α (smaller) and stricter ξ (smaller) need ≥ n.
        let stricter = EstimatorParams { alpha: alpha / 2.0, xi: xi / 2.0 };
        let n2 = estimate_sample_size(n_total, stricter).map_err(|e| e.to_string())?;
        if n2 < n - 1e-9 {
            return Err(format!("stricter params gave smaller n: {n2} < {n}"));
        }
        Ok(())
    });
}

#[test]
fn prop_estimator_coverage_on_gaussian_population() {
    // Statistical validation of Lemma 3.2: sampling n per the formula keeps
    // the sample mean within Δ = ξ·|Z̄| of the population mean with
    // frequency ≥ (1-α) - slack.  One big population, many resamples.
    let mut rng = Pcg64::seeded(0xC0FFEE);
    let n_total = 20_000usize;
    // Population with |mean| >> 0 so relative error is well-defined.
    let pop: Vec<f64> = (0..n_total).map(|_| 5.0 + rng.normal()).collect();
    let pop_mean = pop.iter().sum::<f64>() / n_total as f64;

    let p = EstimatorParams { alpha: 0.1, xi: 0.01 };
    // Exact Lemma-3.2 sample size with known s² = 1 and Δ = ξ·|Z̄|:
    let u = p.u_half_alpha();
    let delta = p.xi * pop_mean.abs();
    let s2 = 1.0;
    let n = ((n_total as f64) * u * u * s2
        / (delta * delta * n_total as f64 + u * u * s2))
        .ceil() as usize;

    let trials = 400;
    let mut hits = 0;
    for _ in 0..trials {
        let idx = rng.sample_indices(n_total, n);
        let mean: f64 = idx.iter().map(|&i| pop[i]).sum::<f64>() / n as f64;
        if (mean - pop_mean).abs() < delta {
            hits += 1;
        }
    }
    let coverage = hits as f64 / trials as f64;
    assert!(
        coverage >= 1.0 - p.alpha - 0.05,
        "coverage {coverage} below {}",
        1.0 - p.alpha - 0.05
    );
}

// ---------------------------------------------------------------------
// Whole-run invariants (virtual driver)
// ---------------------------------------------------------------------

fn quick_problem(machines: usize, seed: u64) -> KrrProblem {
    let spec = KrrProblemSpec {
        config: "prop".into(),
        d: 3,
        l: 8,
        zeta: 32,
        machines,
        noise: 0.05,
        lambda: 0.02,
        bandwidth: 1.0,
        eval_rows: 32,
        seed,
    };
    KrrProblem::generate(&spec).unwrap()
}

#[test]
fn prop_run_accounting_consistent() {
    check("run_accounting", 25, |rng| {
        let m = 2 + rng.below(8) as usize;
        let gamma = 1 + rng.below(m as u64) as usize;
        let iters = 20 + rng.below(50);
        let p = quick_problem(m, rng.next_u64());
        let cluster = ClusterSpec {
            workers: m,
            delay: DelayModel::LogNormal { mu: -5.0, sigma: 1.2 },
            seed: rng.next_u64(),
            ..ClusterSpec::default()
        };
        let cfg = RunConfig {
            mode: SyncMode::Hybrid { gamma },
            optimizer: OptimizerKind::sgd(0.5),
            loss_form: LossForm::krr(p.spec.lambda),
            eval_every: 0,
            ..RunConfig::default()
        }
        .with_iters(iters);
        let mut pool = p.native_pool();
        let rep = sim::run_virtual(&mut pool, &cluster, &cfg, &NoEval)
            .map_err(|e| e.to_string())?;

        // No failures injected: every iteration includes exactly γ and
        // abandons exactly m-γ.
        let expect_contrib = gamma as u64 * iters;
        let expect_abandoned = (m - gamma) as u64 * iters;
        if rep.total_contributions != expect_contrib {
            return Err(format!(
                "contributions {} want {expect_contrib}",
                rep.total_contributions
            ));
        }
        if rep.total_abandoned != expect_abandoned {
            return Err(format!(
                "abandoned {} want {expect_abandoned}",
                rep.total_abandoned
            ));
        }
        // Per-row sanity.
        for row in rep.recorder.rows() {
            if row.included != gamma {
                return Err(format!("row {} included {}", row.iter, row.included));
            }
            if row.alive != m {
                return Err(format!("row {} alive {}", row.iter, row.alive));
            }
            if !row.loss.is_finite() {
                return Err(format!("row {} loss not finite", row.iter));
            }
        }
        // Virtual clock strictly increases.
        for w in rep.recorder.rows().windows(2) {
            if w[1].time <= w[0].time {
                return Err(format!("time not increasing at iter {}", w[1].iter));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_gamma_m_equals_bsp_without_failures() {
    // Hybrid with γ = M must produce *exactly* the BSP trajectory: same
    // included set (everyone) every iteration.
    check("gamma_m_is_bsp", 15, |rng| {
        let m = 2 + rng.below(6) as usize;
        let p = quick_problem(m, rng.next_u64());
        let cluster = ClusterSpec {
            workers: m,
            delay: DelayModel::LogNormal { mu: -5.0, sigma: 1.0 },
            seed: rng.next_u64(),
            ..ClusterSpec::default()
        };
        let mk = |mode| {
            RunConfig {
                mode,
                optimizer: OptimizerKind::sgd(0.5),
                loss_form: LossForm::krr(p.spec.lambda),
                eval_every: 0,
                ..RunConfig::default()
            }
            .with_iters(30)
        };
        let mut pool1 = p.native_pool();
        let bsp = sim::run_virtual(&mut pool1, &cluster, &mk(SyncMode::Bsp), &NoEval)
            .map_err(|e| e.to_string())?;
        let mut pool2 = p.native_pool();
        let hyb = sim::run_virtual(
            &mut pool2,
            &cluster,
            &mk(SyncMode::Hybrid { gamma: m }),
            &NoEval,
        )
        .map_err(|e| e.to_string())?;
        if bsp.theta != hyb.theta {
            return Err("theta trajectories diverged".into());
        }
        if bsp.total_time() != hyb.total_time() {
            return Err(format!(
                "times diverged: {} vs {}",
                bsp.total_time(),
                hyb.total_time()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_abandon_rate_matches_gamma_fraction() {
    check("abandon_rate_formula", 20, |rng| {
        let m = 4 + rng.below(8) as usize;
        let gamma = 1 + rng.below(m as u64 - 1) as usize;
        let p = quick_problem(m, rng.next_u64());
        let cluster = ClusterSpec {
            workers: m,
            delay: DelayModel::Exponential { rate: 200.0 },
            seed: rng.next_u64(),
            ..ClusterSpec::default()
        };
        let cfg = RunConfig {
            mode: SyncMode::Hybrid { gamma },
            optimizer: OptimizerKind::sgd(0.5),
            loss_form: LossForm::krr(p.spec.lambda),
            eval_every: 0,
            ..RunConfig::default()
        }
        .with_iters(40);
        let mut pool = p.native_pool();
        let rep = sim::run_virtual(&mut pool, &cluster, &cfg, &NoEval)
            .map_err(|e| e.to_string())?;
        let want = 1.0 - gamma as f64 / m as f64;
        if (rep.abandon_rate() - want).abs() > 1e-9 {
            return Err(format!("abandon {} want {want}", rep.abandon_rate()));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Async-mode invariants
// ---------------------------------------------------------------------

#[test]
fn prop_async_applies_every_update_exactly_once() {
    check("async_update_count", 15, |rng| {
        let m = 2 + rng.below(6) as usize;
        let updates = 50 + rng.below(100);
        let p = quick_problem(m, rng.next_u64());
        let cluster = ClusterSpec {
            workers: m,
            delay: DelayModel::Exponential { rate: 100.0 },
            seed: rng.next_u64(),
            ..ClusterSpec::default()
        };
        let cfg = RunConfig {
            mode: SyncMode::Async { damping: 0.0 },
            optimizer: OptimizerKind::sgd(0.2),
            loss_form: LossForm::krr(p.spec.lambda),
            eval_every: 0,
            ..RunConfig::default()
        }
        .with_iters(updates);
        let mut pool = p.native_pool();
        let rep = sim::run_virtual(&mut pool, &cluster, &cfg, &NoEval)
            .map_err(|e| e.to_string())?;
        if rep.total_contributions != updates {
            return Err(format!(
                "applied {} updates, want {updates}",
                rep.total_contributions
            ));
        }
        let st = rep.mean_staleness.ok_or("no staleness recorded")?;
        // Staleness is bounded by the cluster size in steady state (every
        // worker holds at most one in-flight computation).
        if st < 0.0 || st > m as f64 {
            return Err(format!("mean staleness {st} outside [0, {m}]"));
        }
        Ok(())
    });
}

#[test]
fn prop_async_damping_shrinks_stale_steps() {
    // With heavy damping the same event sequence must move θ strictly less
    // (in total distance) than undamped async whenever staleness occurs.
    check("async_damping_contracts", 10, |rng| {
        let m = 4 + rng.below(4) as usize;
        let p = quick_problem(m, rng.next_u64());
        let cluster = ClusterSpec {
            workers: m,
            delay: DelayModel::Exponential { rate: 50.0 },
            seed: rng.next_u64(),
            ..ClusterSpec::default()
        };
        let run = |damping: f64| {
            let cfg = RunConfig {
                mode: SyncMode::Async { damping },
                optimizer: OptimizerKind::sgd(0.2),
                loss_form: LossForm::krr(p.spec.lambda),
                eval_every: 0,
                ..RunConfig::default()
            }
            .with_iters(60);
            let mut pool = p.native_pool();
            sim::run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap()
        };
        let plain = run(0.0);
        let damped = run(4.0);
        let d_plain = hybriditer::math::vec_ops::norm2(&plain.theta);
        let d_damped = hybriditer::math::vec_ops::norm2(&damped.theta);
        // From θ=0 both descend toward θ*; the damped run cannot overshoot
        // the plain run's travel distance.
        if d_damped > d_plain * 1.5 + 1e-6 {
            return Err(format!("damped moved further: {d_damped} vs {d_plain}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// BSP-retry reassignment invariants
// ---------------------------------------------------------------------

#[test]
fn prop_bsp_retry_all_shards_contribute_every_iteration() {
    use hybriditer::coordinator::BspRecovery;
    use hybriditer::straggler::FailureModel;
    check("bsp_retry_full_inclusion", 10, |rng| {
        let m = 4 + rng.below(6) as usize;
        let iters = 40;
        let p = quick_problem(m, rng.next_u64());
        let cluster = ClusterSpec {
            workers: m,
            delay: DelayModel::LogNormal { mu: -5.0, sigma: 0.8 },
            failure: FailureModel {
                crash_prob: 0.02,
                transient_prob: 0.05,
                rejoin_after: None,
            },
            // Keep at least half the cluster immortal so retry can always
            // reassign somewhere.
            failure_only: (0..m / 2).collect(),
            seed: rng.next_u64(),
            ..ClusterSpec::default()
        };
        let cfg = RunConfig {
            mode: SyncMode::Bsp,
            optimizer: OptimizerKind::sgd(0.5),
            loss_form: LossForm::krr(p.spec.lambda),
            bsp_recovery: BspRecovery::Retry { detect_timeout: 0.05 },
            eval_every: 0,
            ..RunConfig::default()
        }
        .with_iters(iters);
        let mut pool = p.native_pool();
        let rep = sim::run_virtual(&mut pool, &cluster, &cfg, &NoEval)
            .map_err(|e| e.to_string())?;
        if !rep.status.is_healthy() {
            return Err(format!("bsp-retry did not survive: {:?}", rep.status));
        }
        // Retry semantics: every iteration aggregates all m shards.
        for row in rep.recorder.rows() {
            if row.included != m {
                return Err(format!(
                    "iter {}: included {} shards, want {m}",
                    row.iter, row.included
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_checkpoint_roundtrip_random() {
    use hybriditer::data::Checkpoint;
    check("checkpoint_roundtrip", 50, |rng| {
        let n = rng.below(5000) as usize;
        let mut theta = vec![0.0f32; n];
        rng.fill_normal(&mut theta, 0.0, 10.0);
        let ckpt = Checkpoint::new(theta, rng.next_u64());
        let mut buf = Vec::new();
        ckpt.write_to(&mut buf).map_err(|e| e.to_string())?;
        let back = Checkpoint::read_from(&mut std::io::Cursor::new(buf))
            .map_err(|e| e.to_string())?;
        if back != ckpt {
            return Err("roundtrip mismatch".into());
        }
        Ok(())
    });
}
