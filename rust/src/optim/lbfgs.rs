//! Limited-memory BFGS (two-loop recursion) with a fixed step size.
//!
//! Line search over a *distributed partial* gradient would need extra
//! synchronization rounds (defeating the paper's point), so this master
//! uses the standard stochastic-L-BFGS compromise: two-loop direction with
//! a constant η and curvature-pair skipping when `s·y ≤ ε`.

use super::Optimizer;
use crate::math::vec_ops;
use std::collections::VecDeque;

#[derive(Clone, Debug)]
pub struct Lbfgs {
    eta: f64,
    history: usize,
    pairs: VecDeque<(Vec<f32>, Vec<f32>, f64)>, // (s, y, 1/(y·s))
    prev: Option<(Vec<f32>, Vec<f32>)>,         // (theta, grad) at t-1
}

impl Lbfgs {
    pub fn new(eta: f64, history: usize) -> Lbfgs {
        Lbfgs {
            eta,
            history: history.max(1),
            pairs: VecDeque::new(),
            prev: None,
        }
    }

    /// Two-loop recursion: approximate `H·g`.
    fn direction(&self, grad: &[f32]) -> Vec<f32> {
        let mut q: Vec<f32> = grad.to_vec();
        let k = self.pairs.len();
        let mut alphas = vec![0.0f64; k];
        for (i, (s, y, rho)) in self.pairs.iter().enumerate().rev() {
            let alpha = rho * vec_ops::dot(s, &q);
            alphas[i] = alpha;
            vec_ops::axpy(-(alpha as f32), y, &mut q);
        }
        // Initial Hessian scaling: γ_k = (s·y)/(y·y) of the newest pair.
        if let Some((s, y, _)) = self.pairs.back() {
            let sy = vec_ops::dot(s, y);
            let yy = vec_ops::dot(y, y);
            if yy > 0.0 {
                let scale = (sy / yy) as f32;
                vec_ops::scale(&mut q, scale);
            }
        }
        for (i, (s, y, rho)) in self.pairs.iter().enumerate() {
            let beta = rho * vec_ops::dot(y, &q);
            vec_ops::axpy((alphas[i] - beta) as f32, s, &mut q);
        }
        q
    }
}

impl Optimizer for Lbfgs {
    fn step(&mut self, theta: &mut [f32], grad: &[f32], _iter: u64) {
        // Update curvature history from the previous step.
        if let Some((ptheta, pgrad)) = self.prev.take() {
            let s: Vec<f32> = theta.iter().zip(&ptheta).map(|(a, b)| a - b).collect();
            let y: Vec<f32> = grad.iter().zip(&pgrad).map(|(a, b)| a - b).collect();
            let sy = vec_ops::dot(&s, &y);
            if sy > 1e-10 {
                if self.pairs.len() == self.history {
                    self.pairs.pop_front();
                }
                self.pairs.push_back((s, y, 1.0 / sy));
            }
        }
        self.prev = Some((theta.to_vec(), grad.to_vec()));

        let dir = self.direction(grad);
        vec_ops::axpy(-(self.eta as f32), &dir, theta);
    }

    fn name(&self) -> &'static str {
        "lbfgs"
    }

    fn reset(&mut self) {
        self.pairs.clear();
        self.prev = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_is_gradient_descent() {
        let mut o = Lbfgs::new(0.1, 5);
        let mut theta = vec![1.0f32, 1.0];
        o.step(&mut theta, &[1.0, 2.0], 0);
        assert!((theta[0] - 0.9).abs() < 1e-6);
        assert!((theta[1] - 0.8).abs() < 1e-6);
    }

    #[test]
    fn history_is_bounded() {
        let mut o = Lbfgs::new(0.01, 3);
        let mut theta = vec![0.0f32; 4];
        for it in 0..20 {
            let g: Vec<f32> = theta.iter().map(|t| t - 1.0).collect();
            o.step(&mut theta, &g, it);
        }
        assert!(o.pairs.len() <= 3);
    }

    #[test]
    fn beats_sgd_on_illconditioned_quadratic() {
        // curvatures span 100x; L-BFGS should converge much faster than a
        // step-size-limited SGD.
        let curv = [100.0f32, 1.0, 10.0, 0.5];
        let run = |opt: &mut dyn Optimizer, iters: u64| -> f64 {
            let mut x = vec![1.0f32; 4];
            let mut g = vec![0.0f32; 4];
            for it in 0..iters {
                for i in 0..4 {
                    g[i] = curv[i] * x[i];
                }
                opt.step(&mut x, &g, it);
            }
            vec_ops::norm2(&x)
        };
        let mut sgd = crate::optim::Sgd::new(crate::optim::EtaSchedule::constant(0.009));
        let mut lb = Lbfgs::new(0.5, 8);
        let e_sgd = run(&mut sgd, 60);
        let e_lb = run(&mut lb, 60);
        assert!(e_lb < e_sgd * 0.1, "lbfgs {e_lb} vs sgd {e_sgd}");
    }

    #[test]
    fn converges_on_quadratic() {
        let mut o = Lbfgs::new(0.5, 7);
        let err = crate::optim::test_util::run_quadratic(&mut o, 100);
        assert!(err < 1e-4, "err={err}");
    }
}
