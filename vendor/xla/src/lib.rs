//! Offline stub of the `xla-rs` PJRT bindings.
//!
//! The container building this workspace has no native XLA/PJRT runtime,
//! so this crate provides the exact API surface `hybriditer::runtime` and
//! the XLA compute pools consume:
//!
//! * [`Literal`] is a **real** host-side tensor implementation (typed flat
//!   storage + dims) — literal marshalling round-trips and its unit tests
//!   pass without any native code;
//! * [`PjRtClient`]/[`PjRtBuffer`] work as host-memory handles;
//! * [`PjRtClient::compile`] is **gated**: it returns a clear
//!   "runtime unavailable" error, so every XLA-backed path fails fast at
//!   artifact-load time while the pure-rust mirror keeps tests and benches
//!   fully functional.  Integration tests already skip when artifacts are
//!   absent, which is always the case in this environment.
//!
//! Swapping the real bindings back in is a one-line `Cargo.toml` change.

use std::borrow::Borrow;
use std::fmt;
use std::rc::Rc;

/// Errors surfaced by the (stub) XLA boundary.
#[derive(Debug, Clone)]
pub enum Error {
    /// The operation needs the native XLA/PJRT runtime, which this build
    /// does not link.
    Unavailable(String),
    /// Shape/dtype problem in host-side literal handling.
    Shape(String),
    /// I/O problem reading an artifact file.
    Io(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(msg) => write!(f, "xla runtime unavailable: {msg}"),
            Error::Shape(msg) => write!(f, "xla shape error: {msg}"),
            Error::Io(msg) => write!(f, "xla io error: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error::Unavailable(format!(
        "{what} requires the native XLA/PJRT runtime; this build uses the \
         offline stub (vendor/xla) — use the native backend instead"
    ))
}

/// Element type of a literal, mirroring the PJRT naming (`S32` = i32).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U32,
}

/// Sealed-ish marker for element types the stub stores natively.
pub trait NativeType: Copy + 'static {
    const TY: ElementType;
    fn to_store(data: &[Self]) -> Store;
    fn from_store(store: &Store) -> Option<Vec<Self>>;
}

/// Typed flat storage behind a [`Literal`].
#[derive(Clone, Debug, PartialEq)]
pub enum Store {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    Tuple(Vec<Literal>),
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn to_store(data: &[Self]) -> Store {
        Store::F32(data.to_vec())
    }
    fn from_store(store: &Store) -> Option<Vec<Self>> {
        match store {
            Store::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn to_store(data: &[Self]) -> Store {
        Store::I32(data.to_vec())
    }
    fn from_store(store: &Store) -> Option<Vec<Self>> {
        match store {
            Store::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for u32 {
    const TY: ElementType = ElementType::U32;
    fn to_store(data: &[Self]) -> Store {
        Store::U32(data.to_vec())
    }
    fn from_store(store: &Store) -> Option<Vec<Self>> {
        match store {
            Store::U32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A host-side tensor: typed flat data plus dims.  Rank-0 = scalar.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    store: Store,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal {
            store: T::to_store(&[v]),
            dims: vec![],
        }
    }

    /// Rank-1 literal from a flat slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            store: T::to_store(data),
            dims: vec![data.len() as i64],
        }
    }

    /// Build a tuple literal (what `return_tuple=True` entry points yield).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal {
            store: Store::Tuple(elems),
            dims: vec![],
        }
    }

    /// Reinterpret with new dims; the element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if want != have {
            return Err(Error::Shape(format!(
                "reshape to {dims:?} ({want} elements) from {have} elements"
            )));
        }
        Ok(Literal {
            store: self.store.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Number of elements (tuples report their arity).
    pub fn element_count(&self) -> usize {
        match &self.store {
            Store::F32(v) => v.len(),
            Store::I32(v) => v.len(),
            Store::U32(v) => v.len(),
            Store::Tuple(v) => v.len(),
        }
    }

    /// Element type (errors on tuples, as the real bindings do).
    pub fn ty(&self) -> Result<ElementType> {
        match &self.store {
            Store::F32(_) => Ok(ElementType::F32),
            Store::I32(_) => Ok(ElementType::S32),
            Store::U32(_) => Ok(ElementType::U32),
            Store::Tuple(_) => Err(Error::Shape("tuple literal has no element type".into())),
        }
    }

    /// Flat copy of the data as `T` (dtype must match).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_store(&self.store).ok_or_else(|| {
            Error::Shape(format!(
                "literal holds {:?}, asked for {:?}",
                self.ty(),
                T::TY
            ))
        })
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.store {
            Store::Tuple(v) => Ok(v),
            _ => Err(Error::Shape("literal is not a tuple".into())),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Device buffer handle.  In the stub, "device" memory is host memory.
#[derive(Clone, Debug)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// PJRT client handle.  `Rc`-based like the real bindings (not `Send`):
/// each thread builds its own.
#[derive(Clone)]
pub struct PjRtClient {
    inner: Rc<()>,
}

impl PjRtClient {
    /// Create a CPU client.  Succeeds in the stub so hosts can build
    /// buffers; only compilation/execution is gated.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { inner: Rc::new(()) })
    }

    pub fn platform_name(&self) -> String {
        let _ = &self.inner;
        "stub-cpu".to_string()
    }

    /// Upload a host slice as a device buffer.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let n: usize = dims.iter().product();
        if !dims.is_empty() && data.len() != n {
            return Err(Error::Shape(format!(
                "buffer of {} elements for dims {dims:?}",
                data.len()
            )));
        }
        let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        Ok(PjRtBuffer {
            literal: Literal {
                store: T::to_store(data),
                dims,
            },
        })
    }

    /// Compile an HLO computation.  Always fails in the stub.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module text (the stub only checks the file is readable).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Io(format!("{path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation wrapping a module proto.
pub struct XlaComputation {
    #[allow(dead_code)]
    proto: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: () }
    }
}

/// A compiled executable.  Unreachable through the stub (compile fails),
/// but the type and methods exist so callers typecheck.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b<B: Borrow<PjRtBuffer>>(&self, _inputs: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
        assert_eq!(l.ty().unwrap(), ElementType::F32);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn scalar_and_tuple() {
        let s = Literal::scalar(7i32);
        assert_eq!(s.element_count(), 1);
        assert_eq!(s.ty().unwrap(), ElementType::S32);
        let t = Literal::tuple(vec![s.clone(), Literal::scalar(1.5f32)]);
        assert!(t.ty().is_err());
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0], s);
    }

    #[test]
    fn client_buffers_work_but_compile_is_gated() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "stub-cpu");
        let b = c.buffer_from_host_buffer(&[1.0f32, 2.0], &[2], None).unwrap();
        assert_eq!(b.to_literal_sync().unwrap().to_vec::<f32>().unwrap(), vec![1.0, 2.0]);
        let dir = std::env::temp_dir().join("xla_stub_test.hlo.txt");
        std::fs::write(&dir, "HloModule m").unwrap();
        let proto = HloModuleProto::from_text_file(dir.to_str().unwrap()).unwrap();
        let comp = XlaComputation::from_proto(&proto);
        assert!(matches!(c.compile(&comp), Err(Error::Unavailable(_))));
    }
}
