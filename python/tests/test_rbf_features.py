"""L1 correctness: pallas RBF random-feature kernel vs oracle, plus the
statistical property that makes it the paper's K[x]: the feature inner
product approximates the RBF kernel."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import rbf_features as rf
from compile.kernels import ref


def _mk(m, d, l, seed, sigma=1.0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 1, (m, d)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 1.0 / sigma, (d, l)), jnp.float32)
    b = jnp.asarray(rng.uniform(0, 2 * np.pi, l), jnp.float32)
    return x, w, b


class TestRbfFeatures:
    def test_matches_ref(self):
        x, w, b = _mk(300, 8, 64, 0)
        np.testing.assert_allclose(
            np.asarray(rf.rbf_features(x, w, b)),
            np.asarray(ref.rbf_features(x, w, b)),
            rtol=1e-5, atol=1e-5,
        )

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(1, 400),
        d=st.integers(1, 16),
        l=st.sampled_from([8, 32, 64]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, m, d, l, seed):
        x, w, b = _mk(m, d, l, seed)
        got = np.asarray(rf.rbf_features(x, w, b))
        want = np.asarray(ref.rbf_features(x, w, b))
        assert got.shape == (m, l)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_bounded(self):
        x, w, b = _mk(100, 4, 32, 1)
        phi = np.asarray(rf.rbf_features(x, w, b))
        bound = np.sqrt(2.0 / 32) + 1e-6
        assert np.all(np.abs(phi) <= bound)

    def test_kernel_approximation(self):
        """phi(x)^T phi(x') -> exp(-||x-x'||^2 / 2 sigma^2) as l grows."""
        sigma = 1.5
        m, d, l = 24, 4, 8192
        x, w, b = _mk(m, d, l, 2, sigma=sigma)
        phi = np.asarray(ref.rbf_features(x, w, b))
        approx = phi @ phi.T
        xs = np.asarray(x)
        d2 = ((xs[:, None, :] - xs[None, :, :]) ** 2).sum(-1)
        exact = np.exp(-d2 / (2 * sigma * sigma))
        assert np.abs(approx - exact).max() < 0.08

    def test_block_size_invariance(self):
        x, w, b = _mk(384, 8, 32, 3)
        a = np.asarray(rf.rbf_features(x, w, b, block_m=32))
        c = np.asarray(rf.rbf_features(x, w, b, block_m=384))
        np.testing.assert_allclose(a, c, rtol=1e-6, atol=1e-6)
