//! Hand-rolled scoped worker pool (the vendor set has no rayon):
//! deterministic-order parallel map over independent work items.
//!
//! Used by the bench suite's sweep engine ([`crate::bench_harness::sweep`])
//! to run (γ × drop × seed) points concurrently.  Results come back in
//! *input order* regardless of completion order, so sweep tables and CSVs
//! are byte-identical to a serial run of the same points.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// Process-wide default pool size: 0 means "ask the OS"
/// (`std::thread::available_parallelism`).  Set from the `--threads` CLI
/// flag or the `[bench] threads` config key.
static DEFAULT_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Override the default pool size (0 restores auto-detection).
pub fn set_default_threads(n: usize) {
    DEFAULT_THREADS.store(n, Ordering::Relaxed);
}

/// The pool size [`scoped_map`] callers should use when none is given:
/// the configured override, else available parallelism, else 1.
pub fn default_threads() -> usize {
    match DEFAULT_THREADS.load(Ordering::Relaxed) {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        n => n,
    }
}

/// Map `f` over `items` on up to `threads` scoped worker threads, returning
/// results in input order.  Work is pulled from a shared atomic cursor, so
/// uneven item costs self-balance.  `f(i, &items[i])` receives the item's
/// index for seed derivation.  A panic inside `f` propagates to the caller
/// once the scope joins (no result is silently dropped).
pub fn scoped_map<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }

    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    let mut out: Vec<Option<R>> = Vec::with_capacity(items.len());
    out.resize_with(items.len(), || None);

    std::thread::scope(|scope| {
        for _ in 0..threads {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(i, &items[i]);
                if tx.send((i, r)).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        // Collect until every sender is gone; placement by index restores
        // deterministic input order.
        for (i, r) in rx {
            out[i] = Some(r);
        }
    });

    out.into_iter()
        .map(|o| o.expect("pool worker died before finishing its items"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = scoped_map(4, &items, |i, &x| {
            // Stagger completion: later items finish first.
            if i % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            x * 2
        });
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn matches_serial_run() {
        let items: Vec<u64> = (0..37).collect();
        let serial = scoped_map(1, &items, |i, &x| x * 31 + i as u64);
        let parallel = scoped_map(8, &items, |i, &x| x * 31 + i as u64);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn handles_empty_and_single() {
        let empty: Vec<u8> = vec![];
        assert!(scoped_map(4, &empty, |_, &x| x).is_empty());
        assert_eq!(scoped_map(4, &[5u8], |_, &x| x + 1), vec![6]);
    }

    #[test]
    fn index_matches_item() {
        let items: Vec<usize> = (0..64).collect();
        let out = scoped_map(6, &items, |i, &x| (i, x));
        for (i, (gi, gx)) in out.into_iter().enumerate() {
            assert_eq!(i, gi);
            assert_eq!(i, gx);
        }
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
        set_default_threads(3);
        assert_eq!(default_threads(), 3);
        set_default_threads(0);
        assert!(default_threads() >= 1);
    }
}
