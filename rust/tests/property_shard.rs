//! Property tests for shard-ownership invariants under elastic
//! rebalancing (own helper — proptest is not in the offline vendor set).
//!
//! The contract every rebalance plan must honour:
//! * **conservation** — the total row count across workers is unchanged,
//!   and with nobody alive the unadoptable shards are surfaced in
//!   `RebalancePlan::orphans` rather than silently forgotten;
//! * **exclusivity** — no row (shard) is owned by two workers;
//! * **liveness** — after applying the plan, every owner is alive
//!   (whenever at least one worker is);
//! * **balance** — alive loads differ by at most one shard (uniform
//!   weights) / by less than one from the fractional capacity quota
//!   (weighted largest-remainder apportionment);
//! * **identity** — `split_even`'s layout round-trips through rebalance to
//!   itself when membership is unchanged;
//! * **uniform equivalence** — any uniform weight vector reproduces the
//!   legacy plan exactly, move lists included.

use hybriditer::data::{plan_rebalance, plan_rebalance_weighted, OwnershipMap};
use hybriditer::util::proptest::check;
use hybriditer::util::rng::Pcg64;

/// Draw a random ownership map (every shard assigned to some worker) plus
/// a random liveness mask.
fn random_state(rng: &mut Pcg64) -> (OwnershipMap, Vec<bool>) {
    let workers = 1 + rng.below(12) as usize;
    let shards = 1 + rng.below(24) as usize;
    let mut map = OwnershipMap::even(shards, workers);
    // Scramble: random reassignments keep the "exactly one owner" shape
    // but produce arbitrary load skew.
    for s in 0..shards {
        map.reassign(s, rng.below(workers as u64) as usize);
    }
    let alive: Vec<bool> = (0..workers).map(|_| rng.next_f64() < 0.7).collect();
    (map, alive)
}

/// Reconstruct per-worker shard sets and check conservation + exclusivity.
fn check_partition(map: &OwnershipMap) -> Result<(), String> {
    let mut seen = vec![0usize; map.shards()];
    let mut total = 0usize;
    for w in 0..map.workers() {
        for s in map.shards_of(w) {
            seen[s] += 1;
            total += 1;
        }
    }
    if total != map.shards() {
        return Err(format!("{total} shard assignments for {} shards", map.shards()));
    }
    if let Some(s) = seen.iter().position(|&c| c != 1) {
        return Err(format!("shard {s} owned {} times", seen[s]));
    }
    Ok(())
}

#[test]
fn prop_rebalance_preserves_rows_and_exclusive_ownership() {
    check("rebalance_conservation", 400, |rng| {
        let (mut map, alive) = random_state(rng);
        let before_shards = map.shards();
        let plan = plan_rebalance(&map, &alive);
        map.apply(&plan).map_err(|e| e.to_string())?;
        if map.shards() != before_shards {
            return Err(format!("shards {} -> {}", before_shards, map.shards()));
        }
        check_partition(&map)
    });
}

#[test]
fn prop_rebalance_owners_alive_and_loads_level() {
    check("rebalance_liveness_balance", 400, |rng| {
        let (mut map, alive) = random_state(rng);
        let plan = plan_rebalance(&map, &alive);
        map.apply(&plan).map_err(|e| e.to_string())?;
        if !alive.iter().any(|&a| a) {
            // Nobody alive: the plan must be empty (nowhere to move work).
            if !plan.is_empty() {
                return Err("plan non-empty with everyone dead".into());
            }
            return Ok(());
        }
        for s in 0..map.shards() {
            if !alive[map.owner(s)] {
                return Err(format!("shard {s} owned by dead worker {}", map.owner(s)));
            }
        }
        let loads: Vec<usize> = (0..map.workers())
            .filter(|&w| alive[w])
            .map(|w| map.load(w))
            .collect();
        let (lo, hi) = (
            *loads.iter().min().unwrap(),
            *loads.iter().max().unwrap(),
        );
        if hi - lo > 1 {
            return Err(format!("loads not level: min {lo}, max {hi}"));
        }
        Ok(())
    });
}

#[test]
fn prop_rebalance_identity_when_membership_unchanged() {
    // split_even's layout (one shard per worker) must round-trip through
    // rebalance to the identity whenever membership is unchanged.
    check("rebalance_identity", 200, |rng| {
        let m = 1 + rng.below(16) as usize;
        let mut map = OwnershipMap::identity(m);
        let plan = plan_rebalance(&map, &vec![true; m]);
        if !plan.is_empty() {
            return Err(format!("identity map produced {} moves", plan.len()));
        }
        map.apply(&plan).map_err(|e| e.to_string())?;
        if map != OwnershipMap::identity(m) {
            return Err("map changed without membership change".into());
        }
        Ok(())
    });
}

#[test]
fn prop_rebalance_is_stable_fixpoint() {
    // Applying plan_rebalance twice on an unchanged mask: the second plan
    // must be empty (rebalancing is idempotent at a boundary).
    check("rebalance_fixpoint", 300, |rng| {
        let (mut map, alive) = random_state(rng);
        let plan = plan_rebalance(&map, &alive);
        map.apply(&plan).map_err(|e| e.to_string())?;
        let again = plan_rebalance(&map, &alive);
        if !again.is_empty() {
            return Err(format!("second plan has {} moves", again.len()));
        }
        Ok(())
    });
}

/// Draw a per-worker weight vector from a small capacity palette.
fn random_weights(rng: &mut Pcg64, workers: usize) -> Vec<f64> {
    const PALETTE: [f64; 5] = [0.25, 0.5, 1.0, 2.0, 4.0];
    (0..workers)
        .map(|_| PALETTE[rng.below(PALETTE.len() as u64) as usize])
        .collect()
}

#[test]
fn prop_weighted_conserves_rows_owners_alive_quota_bound() {
    check("weighted_conservation_liveness_quota", 400, |rng| {
        let (mut map, alive) = random_state(rng);
        let weights = random_weights(rng, map.workers());
        let plan = plan_rebalance_weighted(&map, &alive, &weights);
        map.apply(&plan).map_err(|e| e.to_string())?;
        check_partition(&map)?;
        let alive_workers: Vec<usize> =
            (0..map.workers()).filter(|&w| alive[w]).collect();
        if alive_workers.is_empty() {
            if !plan.is_empty() {
                return Err("plan non-empty with everyone dead".into());
            }
            return Ok(());
        }
        for s in 0..map.shards() {
            if !alive[map.owner(s)] {
                return Err(format!("shard {s} owned by dead worker {}", map.owner(s)));
            }
        }
        // Largest-remainder bound: every alive load is within one of its
        // fractional quota.
        let total: f64 = alive_workers.iter().map(|&w| weights[w]).sum();
        for &w in &alive_workers {
            let quota = map.shards() as f64 * weights[w] / total;
            let load = map.load(w) as f64;
            if (load - quota).abs() >= 1.0 {
                return Err(format!(
                    "worker {w}: load {load} vs quota {quota:.3} (weights {weights:?})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_weighted_uniform_weights_reproduce_legacy_plan() {
    // Any uniform weight vector must produce *exactly* the legacy plan —
    // same moves in the same order — so homogeneous clusters cannot drift
    // from the pre-capacity goldens.
    check("weighted_uniform_equals_legacy", 300, |rng| {
        let (map, alive) = random_state(rng);
        let c = [0.25, 1.0, 3.5][rng.below(3) as usize];
        let weights = vec![c; map.workers()];
        let legacy = plan_rebalance(&map, &alive);
        let weighted = plan_rebalance_weighted(&map, &alive, &weights);
        if legacy != weighted {
            return Err(format!("plans diverged: {legacy:?} vs {weighted:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_weighted_rebalance_is_stable_fixpoint() {
    check("weighted_fixpoint", 300, |rng| {
        let (mut map, alive) = random_state(rng);
        let weights = random_weights(rng, map.workers());
        let plan = plan_rebalance_weighted(&map, &alive, &weights);
        map.apply(&plan).map_err(|e| e.to_string())?;
        let again = plan_rebalance_weighted(&map, &alive, &weights);
        if !again.is_empty() {
            return Err(format!("second plan has {} moves", again.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_orphans_surface_exactly_the_unadoptable_shards() {
    // Conservation across the dead-owner set: every shard owned by a dead
    // worker is either moved (someone is alive) or listed in `orphans`
    // (nobody is) — and never both.
    check("orphan_conservation", 300, |rng| {
        let (map, alive) = random_state(rng);
        let dead_owned: Vec<usize> =
            (0..map.shards()).filter(|&s| !alive[map.owner(s)]).collect();
        let plan = plan_rebalance(&map, &alive);
        let anyone_alive = alive.iter().any(|&a| a);
        if anyone_alive {
            if !plan.orphans.is_empty() {
                return Err(format!("orphans {:?} with workers alive", plan.orphans));
            }
            for &s in &dead_owned {
                if !plan.moves.iter().any(|m| m.shard == s) {
                    return Err(format!("dead-owned shard {s} neither moved nor orphaned"));
                }
            }
        } else {
            if !plan.moves.is_empty() {
                return Err("moves with nobody alive".into());
            }
            if plan.orphans != dead_owned {
                return Err(format!(
                    "orphans {:?} != dead-owned {:?}",
                    plan.orphans, dead_owned
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_crash_then_rejoin_roundtrips_to_balanced() {
    // Leave + rebalance + rejoin + rebalance must restore a level layout
    // covering every shard exactly once (not necessarily the original
    // placement — levelling is allowed to leave adopted shards in place).
    check("crash_rejoin_roundtrip", 200, |rng| {
        let m = 2 + rng.below(10) as usize;
        let mut map = OwnershipMap::identity(m);
        let dead = rng.below(m as u64) as usize;
        let mut alive = vec![true; m];
        alive[dead] = false;
        map.apply(&plan_rebalance(&map, &alive)).map_err(|e| e.to_string())?;
        alive[dead] = true;
        map.apply(&plan_rebalance(&map, &alive)).map_err(|e| e.to_string())?;
        check_partition(&map)?;
        for w in 0..m {
            if map.load(w) != 1 {
                return Err(format!("worker {w} load {} after roundtrip", map.load(w)));
            }
        }
        Ok(())
    });
}
