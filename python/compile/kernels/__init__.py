"""L1 pallas kernels (interpret=True) and their pure-jnp oracle (ref)."""
