//! Fault-tolerance demo (the abstract's "high fault tolerance"): kill
//! workers mid-training and watch each barrier policy cope.
//!
//! * BSP without recovery → stalls the moment a node dies;
//! * BSP with Hadoop-style retry → survives but pays detect+recompute;
//! * HYBRID γ-of-M → doesn't even flinch until fewer than γ nodes remain.
//!
//!     cargo run --release --example fault_tolerance

use hybriditer::bench_harness::{f, Table};
use hybriditer::cluster::ClusterSpec;
use hybriditer::coordinator::{BspRecovery, LossForm, RunConfig, RunStatus, SyncMode};
use hybriditer::data::{KrrProblem, KrrProblemSpec};
use hybriditer::optim::OptimizerKind;
use hybriditer::sim;
use hybriditer::straggler::FailureModel;

fn main() -> anyhow::Result<()> {
    hybriditer::util::logger::init();
    let m = 12;
    let spec = KrrProblemSpec::small().with_machines(m);
    let problem = KrrProblem::generate(&spec)?;

    // A third of the cluster is flaky: each flaky node has 2%/iteration
    // crash probability (no rejoin) plus 5% transient message loss.
    let cluster = ClusterSpec {
        workers: m,
        base_compute: 0.01,
        failure: FailureModel {
            crash_prob: 0.02,
            transient_prob: 0.05,
            rejoin_after: None,
        },
        failure_only: (m - m / 3..m).collect(),
        seed: 2024,
        ..ClusterSpec::default()
    };
    let base = |mode, recovery| RunConfig {
        mode,
        optimizer: OptimizerKind::sgd(1.0),
        loss_form: LossForm::krr(spec.lambda),
        bsp_recovery: recovery,
        eval_every: 50,
        ..RunConfig::default()
    }
    .with_iters(400);

    let mut table = Table::new(
        format!("fault tolerance: {m}-node cluster, {} flaky nodes", m / 3),
        &["policy", "status", "iters_done", "virt_secs", "theta_err", "crashes"],
    );

    let runs = vec![
        ("bsp (no recovery)", base(SyncMode::Bsp, BspRecovery::Stall)),
        (
            "bsp (detect+retry)",
            base(SyncMode::Bsp, BspRecovery::Retry { detect_timeout: 0.05 }),
        ),
        (
            "hybrid gamma=6",
            base(SyncMode::Hybrid { gamma: 6 }, BspRecovery::Stall),
        ),
    ];

    for (name, cfg) in runs {
        let mut pool = problem.native_pool();
        let rep = sim::run_virtual(&mut pool, &cluster, &cfg, &problem)?;
        let status = match &rep.status {
            RunStatus::Completed => "completed".to_string(),
            RunStatus::Converged { iter, .. } => format!("converged@{iter}"),
            RunStatus::Stalled { iter } => format!("STALLED@{iter}"),
            RunStatus::ClusterDead { iter } => format!("DEAD@{iter}"),
        };
        println!("{}", rep.summary());
        table.row(vec![
            name.to_string(),
            status,
            rep.recorder.len().to_string(),
            f(rep.total_time(), 2),
            rep.final_theta_err()
                .map(|e| format!("{e:.3e}"))
                .unwrap_or_else(|| "-".into()),
            rep.crashes.to_string(),
        ]);
    }
    table.print();
    table.save_csv("example_fault_tolerance")?;
    println!(
        "\nReading: BSP without a recovery protocol stalls at the first crash;\n\
         BSP-with-retry survives but pays a detection+recompute penalty each\n\
         failed iteration; the hybrid barrier simply keeps iterating on the\n\
         fastest gamma nodes (the paper's fault-tolerance claim)."
    );
    Ok(())
}
