//! Cluster model: topology spec, typed messages, membership tracking.
//!
//! The paper ran on a physical master/slave cluster; here the cluster is
//! simulated in-process (DESIGN.md §3): workers are OS threads in
//! [`crate::worker`] ("real" timing mode) or discrete-event entities in
//! [`crate::sim`] ("virtual" timing mode).  Both share this module's
//! specification, message, and membership types.

pub mod membership;
pub mod message;

pub use membership::Membership;
pub use message::{MasterMsg, WorkerMsg};

use crate::straggler::{DelayModel, FailureModel, StragglerProfile};

/// How iteration latency is realized.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TimingMode {
    /// Worker threads actually sleep their injected delays; the master
    /// measures wall-clock.  Used by the examples that demonstrate real
    /// time savings.
    Real,
    /// Discrete-event simulation: latencies are bookkept, nothing sleeps.
    /// Deterministic and fast — the default for benches.
    Virtual,
}

/// The cluster an experiment runs on.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    /// Number of slave machines `M`.
    pub workers: usize,
    /// Baseline per-iteration compute time (virtual seconds) of a healthy
    /// worker.  In `Real` mode this also scales the injected sleeps.
    pub base_compute: f64,
    /// Stochastic extra delay, applied to every worker.
    pub delay: DelayModel,
    /// Chronically slow nodes: `(worker index, multiplier)`.
    pub slow_nodes: Vec<(usize, f64)>,
    /// Failure behaviour, applied to every worker (unless `failure_only`
    /// narrows it).
    pub failure: FailureModel,
    /// If non-empty, only these workers get the failure model (the rest are
    /// failure-free) — lets experiments kill *specific* nodes.
    pub failure_only: Vec<usize>,
    /// Master-side per-iteration overhead (aggregate + update), seconds.
    pub master_overhead: f64,
    /// RNG seed for all injected randomness (delays, failures).
    pub seed: u64,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        ClusterSpec {
            workers: 8,
            base_compute: 0.010,
            delay: DelayModel::None,
            slow_nodes: vec![],
            failure: FailureModel::none(),
            failure_only: vec![],
            master_overhead: 0.0005,
            seed: 0x5eed,
        }
    }
}

impl ClusterSpec {
    /// Build each worker's [`StragglerProfile`].
    pub fn profiles(&self) -> Vec<StragglerProfile> {
        (0..self.workers)
            .map(|w| {
                let slow_factor = self
                    .slow_nodes
                    .iter()
                    .find(|(idx, _)| *idx == w)
                    .map(|(_, f)| *f)
                    .unwrap_or(1.0);
                let failure = if self.failure_only.is_empty() || self.failure_only.contains(&w)
                {
                    self.failure.clone()
                } else {
                    FailureModel::none()
                };
                StragglerProfile {
                    base_compute: self.base_compute,
                    slow_factor,
                    delay: self.delay.clone(),
                    failure,
                }
            })
            .collect()
    }

    /// Convenience: mark the last `n` workers as chronically `factor`× slow.
    pub fn with_slow_tail(mut self, n: usize, factor: f64) -> Self {
        assert!(n <= self.workers);
        self.slow_nodes = ((self.workers - n)..self.workers)
            .map(|w| (w, factor))
            .collect();
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_apply_slow_nodes() {
        let spec = ClusterSpec {
            workers: 4,
            slow_nodes: vec![(1, 8.0)],
            ..ClusterSpec::default()
        };
        let ps = spec.profiles();
        assert_eq!(ps.len(), 4);
        assert_eq!(ps[0].slow_factor, 1.0);
        assert_eq!(ps[1].slow_factor, 8.0);
    }

    #[test]
    fn slow_tail_marks_last_workers() {
        let spec = ClusterSpec {
            workers: 6,
            ..ClusterSpec::default()
        }
        .with_slow_tail(2, 4.0);
        assert_eq!(spec.slow_nodes, vec![(4, 4.0), (5, 4.0)]);
    }
}
