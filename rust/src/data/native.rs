//! Pure-rust mirror of the L1/L2 compute: the same math as the pallas
//! kernel (`(1/ζ)Φᵀ(Φθ−y) + λθ`), used
//!
//! * as the reference the XLA path is integration-tested against,
//! * by benches that sweep thousands of virtual iterations where PJRT
//!   dispatch overhead would dominate the thing being measured (straggler
//!   policy behaviour, not kernel speed).

use crate::data::shard::Shard;
use crate::data::{ComputePool, GradResult};
use crate::math::vec_ops;
use crate::Result;

/// One shard's KRR gradient/loss: `g = Φᵀ(Φθ−y)/ζ + λθ`, shared by the
/// pool below and the threaded runtime's per-worker compute (which, under
/// elastic rebalancing, may be handed *any* shard).  `resid` is a scratch
/// buffer grown as needed.
pub fn krr_shard_grad(s: &Shard, lambda: f32, theta: &[f32], resid: &mut Vec<f32>) -> GradResult {
    let (rows, l) = (s.rows, s.l);
    debug_assert_eq!(theta.len(), l);
    if resid.len() < rows {
        resid.resize(rows, 0.0);
    }
    let resid = &mut resid[..rows];

    // r = Φθ − y
    vec_ops::matvec(&s.phi, rows, l, theta, resid);
    let mut ss = 0.0f64;
    for (r, &yi) in resid.iter_mut().zip(s.y.iter()) {
        *r -= yi;
        ss += (*r as f64) * (*r as f64);
    }

    // g = Φᵀ r / ζ + λθ
    let mut grad = vec![0.0f32; l];
    vec_ops::matvec_t(&s.phi, rows, l, resid, &mut grad);
    let inv = 1.0 / rows as f32;
    for (g, &t) in grad.iter_mut().zip(theta.iter()) {
        *g = *g * inv + lambda * t;
    }

    GradResult {
        grad,
        loss_sum: Some(ss),
        examples: rows,
    }
}

/// Native KRR gradient pool over per-worker shards.
pub struct NativeKrrPool {
    shards: Vec<Shard>,
    lambda: f32,
    /// Scratch residual buffer (reused across calls; sized to max shard).
    resid: Vec<f32>,
}

impl NativeKrrPool {
    pub fn new(shards: Vec<Shard>, lambda: f32) -> NativeKrrPool {
        let max_rows = shards.iter().map(|s| s.rows).max().unwrap_or(0);
        NativeKrrPool {
            shards,
            lambda,
            resid: vec![0.0; max_rows],
        }
    }

    pub fn lambda(&self) -> f32 {
        self.lambda
    }
}

impl ComputePool for NativeKrrPool {
    fn dim(&self) -> usize {
        self.shards.first().map(|s| s.l).unwrap_or(0)
    }

    fn n_workers(&self) -> usize {
        self.shards.len()
    }

    fn shard_examples(&self, w: usize) -> usize {
        self.shards[w].rows
    }

    fn grad(&mut self, w: usize, theta: &[f32], _iter: u64) -> Result<GradResult> {
        Ok(krr_shard_grad(&self.shards[w], self.lambda, theta, &mut self.resid))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{KrrProblem, KrrProblemSpec};
    use crate::util::rng::Pcg64;

    fn tiny() -> KrrProblem {
        let spec = KrrProblemSpec {
            config: "test".into(),
            d: 4,
            l: 8,
            zeta: 32,
            machines: 4,
            noise: 0.05,
            lambda: 0.05,
            bandwidth: 1.0,
            eval_rows: 64,
            seed: 3,
        };
        KrrProblem::generate(&spec).unwrap()
    }

    #[test]
    fn zero_gradient_at_shardwise_optimum() {
        // The mean of all shard gradients at θ* must vanish (first-order
        // optimality of eq. 2 over the full training set).
        let p = tiny();
        let mut pool = p.native_pool();
        let m = pool.n_workers();
        let mut mean = vec![0.0f32; p.dim()];
        for w in 0..m {
            let g = pool.grad(w, &p.theta_star, 0).unwrap();
            vec_ops::add_assign(&mut mean, &g.grad);
        }
        vec_ops::scale(&mut mean, 1.0 / m as f32);
        assert!(
            vec_ops::norm2(&mean) < 1e-4,
            "grad at optimum = {}",
            vec_ops::norm2(&mean)
        );
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let p = tiny();
        let mut pool = p.native_pool();
        let mut rng = Pcg64::seeded(5);
        let mut theta = vec![0.0f32; p.dim()];
        rng.fill_normal(&mut theta, 0.0, 1.0);
        let g = pool.grad(0, &theta, 0).unwrap().grad;

        let s = &p.shards[0];
        let f = |t: &[f32]| crate::data::synth::objective(t, &s.phi, &s.y, s.l, p.spec.lambda);
        let eps = 1e-3f32;
        for coord in [0, p.dim() / 2, p.dim() - 1] {
            let mut tp = theta.clone();
            tp[coord] += eps;
            let mut tm = theta.clone();
            tm[coord] -= eps;
            let fd = (f(&tp) - f(&tm)) / (2.0 * eps as f64);
            assert!(
                (fd - g[coord] as f64).abs() < 2e-3,
                "coord {coord}: fd {fd} vs g {}",
                g[coord]
            );
        }
    }

    #[test]
    fn loss_sum_matches_direct() {
        let p = tiny();
        let mut pool = p.native_pool();
        let g = pool.grad(1, &p.theta_true, 0).unwrap();
        let s = &p.shards[1];
        let direct = crate::data::synth::sumsq_residual(&p.theta_true, &s.phi, &s.y, s.l);
        assert!((g.loss_sum.unwrap() - direct).abs() < 1e-6);
        assert_eq!(g.examples, 32);
    }

    #[test]
    fn full_gd_converges_to_theta_star() {
        // Plain full-batch GD with all shards must approach θ* — sanity
        // that data, gradient, and solver agree with each other.
        let p = tiny();
        let mut pool = p.native_pool();
        let m = pool.n_workers();
        let mut theta = vec![0.0f32; p.dim()];
        let mut mean = vec![0.0f32; p.dim()];
        for it in 0..400 {
            mean.fill(0.0);
            for w in 0..m {
                let g = pool.grad(w, &theta, it).unwrap();
                vec_ops::add_assign(&mut mean, &g.grad);
            }
            vec_ops::scale(&mut mean, 1.0 / m as f32);
            vec_ops::axpy(-1.5, &mean, &mut theta);
        }
        let err = p.theta_err(&theta);
        assert!(err < 1e-3, "theta_err={err}");
    }
}
