//! Threaded "real" runtime: slave threads with actual sleeps, a master
//! event loop closing the partial barrier on wall-clock arrivals.
//!
//! This is the production-shaped path: each worker thread owns its own PJRT
//! engine (the `xla` client is not `Send`), receives θ broadcasts over a
//! channel, computes its assigned shards' gradients through the AOT
//! executable, sleeps its injected straggler delay, and reports back.  The
//! master measures *wall-clock* — the examples use this to demonstrate the
//! paper's actual time savings, while benches use the virtual simulator.
//!
//! **Elastic membership** executes the same plan as the virtual driver:
//! scheduled leave/join events ([`crate::cluster::ElasticSchedule`]) apply
//! at iteration boundaries, and with `rebalance_every > 0` the master
//! re-plans shard ownership ([`crate::data::plan_rebalance`]) and ships
//! each worker its current shard list inside every `Work` message.
//! Contributions aggregate in ascending shard order, matching the
//! simulator bit-for-bit on the fold order.  A scheduled leave is a
//! master-side eviction — the slave thread survives, so a later scheduled
//! join simply re-admits it.  Joining a worker that *stochastically*
//! crashed requires a new thread — the old one has stopped serving — so
//! the sync master acts as a supervisor: it spawns a replacement slave on
//! a fresh channel (generation-salted RNG streams; see
//! [`slave::worker_main`]) and re-admits it, exactly like the virtual
//! engine's boundary handler revives a crashed worker on a scheduled
//! join.  Recovery policies ([`crate::recovery`]) hook the same
//! boundaries: `partial-recovery` / `checkpoint-restore` additionally
//! respawn stochastically crashed threads at the next iteration top
//! without waiting for a scheduled join (see `docs/RECOVERY.md`).  The
//! async master still vetoes scheduled joins of crashed threads (async
//! mode rejects non-abandon recovery policies).  The **async** master
//! accepts elastic schedules too: a
//! scheduled event at iteration `k` lands at the update-count boundary
//! `k·M` (the sync-iteration equivalent the virtual engine uses), leaves
//! evict master-side, joins hand the worker a fresh θ snapshot, and with
//! `rebalance_every > 0` each `Work` carries the worker's current shard
//! list whose replies fold as a plain mean.
//!
//! **Unreliable network**: the master wraps its channels in a
//! [`crate::net::NetShim`].  Before each `Work` broadcast it plans the
//! roundtrip (a dropped downlink suppresses the send; injected latency
//! ships inside the message for the slave to sleep), and each received
//! `Grad` is classified by the same pure per-message realization the
//! virtual driver uses — dropped replies are discarded, duplicated ones
//! offered to the barrier twice.  With a lossy spec, BSP degrades to
//! closing on whatever replies can still arrive (the virtual driver
//! instead models Hadoop-style retry; see `docs/NETWORK.md`).

pub mod compute;
pub mod slave;

pub use compute::{NativeKrrFactory, XlaKrrFactory};

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::cluster::{
    ClusterSpec, ElasticKind, ElasticRuntime, MasterMsg, Membership, ShardGrad, WorkerMsg,
};
use crate::coordinator::aggregator::{aggregate, Contribution};
use crate::coordinator::barrier::{Admission, PartialBarrier};
use crate::coordinator::convergence::{ConvergenceTracker, RunStatus};
use crate::coordinator::{BspRecovery, RunConfig, RunReport, SyncMode};
use crate::data::GradResult;
use crate::math::vec_ops;
use crate::metrics::{IterRow, Recorder};
use crate::net::{BlockLedger, BlockSet, GradFate, NetShim, NetStats, ThetaLedger, WorkPlan};
use crate::sim::EvalHooks;
use crate::trace::{self, TraceEvent, TraceSink};
use crate::{Error, Result};

/// Worker-side gradient computation (built inside the worker thread).
/// Shard-addressable: under elastic rebalancing a worker computes whatever
/// shards the master currently assigns it.
///
/// The required method writes into a caller-owned [`GradResult`]; the
/// slave feeds it buffers recycled from the master's free-list
/// ([`MasterMsg::Work`]`::recycle`), so steady-state `Grad` replies reuse
/// their payload `Vec`s instead of allocating per message.
pub trait WorkerCompute {
    fn dim(&self) -> usize;
    /// Compute `shard`'s gradient at `theta` into `out` (grad buffer
    /// resized/overwritten in place).
    fn grad_shard_into(
        &mut self,
        shard: usize,
        theta: &[f32],
        iter: u64,
        out: &mut GradResult,
    ) -> Result<()>;
    /// Allocating convenience wrapper around
    /// [`WorkerCompute::grad_shard_into`].
    fn grad_shard(&mut self, shard: usize, theta: &[f32], iter: u64) -> Result<GradResult> {
        let mut out = GradResult::empty();
        self.grad_shard_into(shard, theta, iter, &mut out)?;
        Ok(out)
    }
    /// Hint: the worker's current assignment.  Implementations holding
    /// per-shard resources (device buffers) may release everything not in
    /// `shards`; migrating a shard back later just re-pays its one upload.
    fn retain_shards(&mut self, shards: &[usize]) {
        let _ = shards;
    }
}

/// Builds per-worker [`WorkerCompute`] instances.  `Sync` because the
/// factory is shared across spawning threads; the built compute is not.
pub trait ComputeFactory: Sync {
    fn dim(&self) -> usize;
    fn workers(&self) -> usize;
    fn shard_examples(&self, w: usize) -> usize;
    /// Called *inside* worker `w`'s thread (PJRT clients are per-thread).
    fn build(&self, w: usize) -> Result<Box<dyn WorkerCompute>>;
}

/// Master receive timeout before declaring a stall (real mode only).
const STALL_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(30);

/// How many iterations an interior-kill fate entry outlives its window
/// before it is pruned — the same straggler horizon the block ledger uses.
const KILL_FATE_HORIZON: u64 = 64;

/// Recycling pool for θ broadcast snapshots.
///
/// The master ships θ to the slaves behind an `Arc`; historically every
/// broadcast cloned the full vector.  The pool instead keeps the shipped
/// buffers and reuses the first one all receivers have dropped
/// (`Arc::get_mut` proves sole ownership), so a steady-state broadcast is
/// a `copy_from_slice`, not an allocation.  Slots only grow while every
/// previous snapshot is still in flight: the sync driver settles on a
/// double buffer (this iteration's broadcast plus stragglers holding last
/// iteration's), the async driver near one per worker plus the θ-ledger's
/// holds.  `tests/alloc_regression.rs` pins the budgets.
struct ThetaPool {
    slots: Vec<Arc<Vec<f32>>>,
}

impl ThetaPool {
    fn new() -> ThetaPool {
        ThetaPool { slots: Vec::new() }
    }

    fn snapshot(&mut self, theta: &[f32]) -> Arc<Vec<f32>> {
        for slot in self.slots.iter_mut() {
            if let Some(buf) = Arc::get_mut(slot) {
                buf.copy_from_slice(theta);
                return Arc::clone(slot);
            }
        }
        let fresh = Arc::new(theta.to_vec());
        self.slots.push(Arc::clone(&fresh));
        fresh
    }
}

/// Apply one scheduled membership event master-side — the threaded
/// counterpart of the virtual engine's boundary handler.  A join of a
/// worker whose thread simulated a stochastic crash is vetoed (its thread
/// stopped serving; re-admitting it would assign shards to a ghost) —
/// the sync master respawns the thread *before* calling this, so the veto
/// only ever fires for the async master, which has no supervisor.
/// Returns whether the event was applied, so callers can keep their own
/// per-event state (the async master's eviction mask) in step.
fn apply_master_event(
    ev: &crate::cluster::ElasticEvent,
    membership: &mut Membership,
    thread_crashed: &[bool],
    boundary: u64,
) -> bool {
    match ev.kind {
        ElasticKind::Join if thread_crashed[ev.worker] => {
            log::warn!(
                "boundary {boundary}: scheduled join of worker {} skipped — \
                 its thread crashed and no supervisor respawn exists",
                ev.worker
            );
            false
        }
        ElasticKind::Join => {
            membership.mark_alive(ev.worker);
            true
        }
        ElasticKind::Leave => {
            membership.mark_down(ev.worker);
            true
        }
    }
}

/// Run an experiment on real threads, measuring wall-clock.
///
/// Tracing is disabled ([`crate::trace::NoopSink`]); use [`run_real_traced`]
/// to attach a flight recorder.
///
/// Deprecated entry point: prefer [`crate::runner::Runner`] with
/// [`crate::runner::Driver::Threaded`]. This thin wrapper is kept so the
/// parity/golden suites stay byte-stable; it can never serve traffic
/// (serving mode is only exposed through `Runner`).
pub fn run_real(
    cluster: &ClusterSpec,
    cfg: &RunConfig,
    factory: &dyn ComputeFactory,
    hooks: &dyn EvalHooks,
) -> Result<RunReport> {
    run_real_serving(cluster, cfg, factory, hooks, &mut crate::trace::NoopSink, None)
}

/// [`run_real`] with a flight-recorder sink attached (see [`crate::trace`]).
///
/// Event timestamps are wall-clock seconds since driver start; the
/// trace-parity oracles in `tests/parity_drivers.rs` compare this driver's
/// journal against the virtual driver's after timestamp normalization.
///
/// Deprecated entry point: prefer [`crate::runner::Runner`] with
/// [`crate::runner::Runner::trace`] attached; see [`run_real`].
pub fn run_real_traced(
    cluster: &ClusterSpec,
    cfg: &RunConfig,
    factory: &dyn ComputeFactory,
    hooks: &dyn EvalHooks,
    sink: &mut dyn TraceSink,
) -> Result<RunReport> {
    run_real_serving(cluster, cfg, factory, hooks, sink, None)
}

/// The one real threaded entry point: [`run_real_traced`] plus an optional
/// serving workload ([`crate::serve`]), reachable only through
/// [`crate::runner::Runner`]. `serve = None` is bit-for-bit the legacy
/// behaviour — the spec rides as an `Option` end to end, so no serving
/// code runs, allocates, or draws randomness without one.
pub(crate) fn run_real_serving(
    cluster: &ClusterSpec,
    cfg: &RunConfig,
    factory: &dyn ComputeFactory,
    hooks: &dyn EvalHooks,
    sink: &mut dyn TraceSink,
    serve: Option<&crate::serve::ServeSpec>,
) -> Result<RunReport> {
    let m = factory.workers();
    if m != cluster.workers {
        return Err(Error::Cluster(format!(
            "factory has {m} workers, cluster spec says {}",
            cluster.workers
        )));
    }
    crate::coordinator::validate_elastic(cluster, &cfg.mode)?;
    cfg.recovery.validate()?;
    if cfg.mode.is_async() {
        if !matches!(cfg.recovery.policy, crate::recovery::RecoveryPolicy::Abandon) {
            return Err(Error::Config(format!(
                "recovery policy '{}' is not supported in async mode (async has \
                 no crash/rejoin barrier to recover at); use 'abandon'",
                cfg.recovery.policy.name()
            )));
        }
        return run_real_async(cluster, cfg, factory, hooks, sink, serve);
    }
    run_real_sync(cluster, cfg, factory, hooks, sink, serve)
}

fn run_real_sync(
    cluster: &ClusterSpec,
    cfg: &RunConfig,
    factory: &dyn ComputeFactory,
    hooks: &dyn EvalHooks,
    sink: &mut dyn TraceSink,
    serve: Option<&crate::serve::ServeSpec>,
) -> Result<RunReport> {
    let driver_start = Instant::now();
    let m = factory.workers();
    let dim = factory.dim();
    // Serving engine (None without a [serve] config): stepped at barrier
    // close keyed on the iteration index — never wall-clock — so the
    // realized arrival/shed/batch sequence is bit-identical to the
    // virtual driver's for the same `(seed, schedule)` (docs/SERVING.md).
    let mut serving = serve.map(crate::serve::ServeEngine::new);
    let n_total: usize = (0..m).map(|w| factory.shard_examples(w)).sum();
    let zeta = factory.shard_examples(0);
    let gamma = cfg.mode.initial_gamma(n_total, zeta, m)?;

    let (res_tx, res_rx) = mpsc::channel::<WorkerMsg>();
    let mut work_txs: Vec<mpsc::Sender<MasterMsg>> = Vec::with_capacity(m);

    let mut theta = cfg.init_theta.clone().unwrap_or_else(|| vec![0.0f32; dim]);
    let mut agg = vec![0.0f32; dim];
    let mut opt = cfg.optimizer.build();
    let mut tracker = ConvergenceTracker::new(cfg.stop.clone());
    let mut rec = Recorder::new();
    let mut membership = Membership::new(m);
    let mut status = RunStatus::Completed;
    // Shard ownership + rebalance state, shared logic with the virtual
    // driver.  A scheduled Leave here is purely master-side (the slave
    // thread survives and is simply not broadcast to), so no extra
    // failure-state bookkeeping is needed in the event hook.
    let mut elastic = ElasticRuntime::new(&membership);
    elastic.configure_capacity(
        cluster.capacity_vec(),
        cluster.warmup_iters,
        cluster.weighted_rebalance,
    );
    // Channel shim realizing the same per-message network fates as the
    // virtual driver's transport.
    let mut shim = NetShim::new(cluster.net.clone(), cluster.seed);
    // Block admission: the shim chunks reply fates into the same block
    // count the virtual transport uses, so both drivers fold identical
    // delivered sets.
    let n_blocks = cluster.net.n_blocks(dim);
    shim.set_block_count(n_blocks);
    let blocking = !shim.is_ideal() && n_blocks > 1;
    let mut ledger = BlockLedger::default();
    let mut stale_blocks_total = 0u64;
    // Threads that simulated a stochastic crash and stopped serving: a
    // scheduled join (or a respawning recovery policy) replaces them with
    // a fresh generation; until then they must not be broadcast to.
    let mut thread_crashed = vec![false; m];
    // Recovery policy state — the same hooks the virtual engine's boundary
    // handler consults, so the drivers cannot drift on when a policy
    // fires.  See `docs/RECOVERY.md`.
    let mut recovery = crate::recovery::RecoveryState::new(cfg.recovery, m);
    let recovering = !recovery.is_noop();
    // Thread generation per worker: respawned slaves salt their RNG
    // streams with it (generation 0 = the historical streams).
    let mut generations = vec![0u64; m];
    // Aggregation overlay (star = the legacy identity, never planned).
    // The overlay is planned at dispatch time from the same pure fate
    // realizations the virtual driver draws from — who relays (the
    // dispatched set), whose reply the network delivers, which interior
    // edges drop — so both drivers kill and deduplicate the identical
    // replies; physical arrival times feed the plan as zeros because
    // fates are time-independent (docs/AGGREGATION.md).
    let topo = !cluster.agg.is_star();
    let topo_ring = cluster.agg.topology == crate::agg::TopologyKind::Ring;
    let mut topo_scratch = crate::agg::AggScratch::new();
    let mut topo_stats = crate::agg::AggStats::default();
    let mut topo_responders: Vec<usize> = Vec::with_capacity(m);
    // Interior-kill fates per worker for replies still physically in
    // flight: a straggling arrival must realize the fate its own window
    // planned.
    let mut killed_hist: Vec<Vec<u64>> = vec![Vec::new(); m];
    // θ broadcast snapshots recycle through this pool — the same
    // zero-steady-state-allocation discipline the virtual driver's
    // arenas follow.
    let mut theta_pool = ThetaPool::new();

    std::thread::scope(|scope| -> Result<()> {
        // --- spawn slaves ------------------------------------------------
        let profiles = cluster.profiles();
        for w in 0..m {
            let (tx, rx) = mpsc::channel::<MasterMsg>();
            work_txs.push(tx);
            let res_tx = res_tx.clone();
            let profile = profiles[w].clone();
            let seed = cluster.seed;
            scope.spawn(move || {
                slave::worker_main(w, seed, profile, 0, factory, rx, res_tx);
            });
        }
        // Supervisor handle: respawned slaves report over clones of this.
        let respawn_tx = res_tx.clone();
        drop(res_tx);
        let mut respawn_buf: Vec<usize> = Vec::new();
        let mut catchups: Vec<crate::recovery::CatchUp> = Vec::new();
        // Master-side compute for catch-up reconstruction, built lazily on
        // the first partial-recovery rejoin (the master thread computes the
        // recovered partition's contribution itself).
        let mut master_compute: Option<Box<dyn WorkerCompute>> = None;
        let mut catchup_grads: Vec<(GradResult, u64)> = Vec::new();

        // Gradient-buffer free-list: payload Vecs from admitted Grad
        // replies are reclaimed here and shipped back to the slaves inside
        // the next Work broadcast, so steady-state replies recycle their
        // buffers through the channel instead of allocating per message.
        let mut free: Vec<Vec<f32>> = Vec::new();
        // Who actually got a Work this iteration — a shard-less worker is
        // skipped entirely, so a crash notice from it must not shrink the
        // deliverable count it never joined.
        let mut dispatched = vec![false; m];
        // Per-worker shard lists behind `Arc`s, rebuilt only when a
        // rebalance actually changes ownership — the dispatch hot path
        // clones the handle, not the list.
        let mut shard_arcs: Vec<Arc<Vec<usize>>> =
            elastic.ownership.grouped().into_iter().map(Arc::new).collect();

        // --- master loop ---------------------------------------------
        'iters: for iter in 0..cfg.stop.max_iters {
            // Recovery actions recorded in an IterRow are this iteration's
            // delta, mirroring the per-iteration network-stat deltas.
            let recov_iter_start = recovery.recoveries;
            let rollback_iter_start = recovery.rollback_iters;
            if recovering {
                // Supervisor respawns: workers that crashed stochastically
                // last iteration come back at this iteration's top
                // (ascending worker order, instant — no warm-up ramp),
                // exactly like the virtual driver's respawn drain.
                recovery.take_respawns(&mut respawn_buf);
                for &w in respawn_buf.iter() {
                    generations[w] += 1;
                    let (tx, rx) = mpsc::channel::<MasterMsg>();
                    work_txs[w] = tx;
                    let res_tx = respawn_tx.clone();
                    let profile = profiles[w].clone();
                    let seed = cluster.seed;
                    let generation = generations[w];
                    scope.spawn(move || {
                        slave::worker_main(w, seed, profile, generation, factory, rx, res_tx);
                    });
                    thread_crashed[w] = false;
                    membership.mark_alive(w);
                    if let Some(rollback) = recovery.on_join(w, iter) {
                        if sink.enabled() {
                            let t = driver_start.elapsed().as_secs_f64();
                            trace::emit_recovery(
                                sink,
                                iter,
                                w,
                                t,
                                recovery.policy().name(),
                                rollback,
                            );
                        }
                    }
                }
                // Snapshot *before* boundary events and the collect loop,
                // so a same-iteration crash restores to this iteration's
                // top — the virtual driver snapshots at the same point.
                recovery.maybe_snapshot(iter, &theta);
            }
            // Elastic membership events land at this boundary, in schedule
            // order, followed by any due rebalance plan — the same
            // primitives the virtual engine's boundary handler uses, so
            // the drivers cannot drift on when a plan is applied.  Warm-up
            // ramps advance first (the engine's boundary handler does the
            // same), and a join that re-admits a down worker starts its
            // ramp.
            elastic.tick_warmup();
            for ev in cluster.elastic.at(iter) {
                let was_down = !membership.is_alive(ev.worker);
                // Supervisor-style respawn: a scheduled join of a worker
                // whose thread simulated a stochastic crash spawns a
                // replacement slave on a fresh channel (the virtual engine
                // likewise revives the crashed failure state on a
                // scheduled join), instead of the historical veto.
                if ev.kind == ElasticKind::Join && thread_crashed[ev.worker] {
                    let w = ev.worker;
                    generations[w] += 1;
                    let (tx, rx) = mpsc::channel::<MasterMsg>();
                    work_txs[w] = tx;
                    let res_tx = respawn_tx.clone();
                    let profile = profiles[w].clone();
                    let seed = cluster.seed;
                    let generation = generations[w];
                    scope.spawn(move || {
                        slave::worker_main(w, seed, profile, generation, factory, rx, res_tx);
                    });
                    thread_crashed[w] = false;
                }
                if apply_master_event(ev, &mut membership, &thread_crashed, iter)
                    && ev.kind == ElasticKind::Join
                    && was_down
                {
                    elastic.note_join(ev.worker);
                }
                if recovering {
                    let fired = match ev.kind {
                        ElasticKind::Leave => recovery.on_leave(ev.worker, iter, &mut theta),
                        ElasticKind::Join => recovery.on_join(ev.worker, iter),
                    };
                    if let Some(rollback) = fired {
                        if sink.enabled() {
                            let t = driver_start.elapsed().as_secs_f64();
                            trace::emit_recovery(
                                sink,
                                iter,
                                ev.worker,
                                t,
                                recovery.policy().name(),
                                rollback,
                            );
                        }
                    }
                }
            }
            // The `rebalance` recovery policy forces a replan at any
            // membership-perturbing boundary — consume the flag exactly
            // like the virtual engine's boundary handler.
            let every = if recovery.take_force_replan() {
                1
            } else {
                cluster.rebalance_every
            };
            let rebalanced = elastic.maybe_rebalance(iter, every, &membership)?;
            if rebalanced {
                log::debug!("iter {iter}: shard ownership rebalanced");
            }
            if sink.enabled() {
                let t = driver_start.elapsed().as_secs_f64();
                let owners = elastic.ownership.owners();
                trace::emit_boundary(sink, &cluster.elastic, iter, rebalanced, owners, t);
            }

            if blocking {
                // Same straggler horizon the virtual driver uses.
                ledger.prune_before(iter.saturating_sub(64));
            }
            let theta_arc = theta_pool.snapshot(&theta);
            if rebalanced {
                for (w, shards) in elastic.ownership.grouped().into_iter().enumerate() {
                    shard_arcs[w] = Arc::new(shards);
                }
            }
            let stats_iter_start = shim.stats();
            let stale_blocks_iter_start = stale_blocks_total;
            let mut deliverable = 0usize;
            dispatched.fill(false);
            topo_responders.clear();
            topo_scratch.arrivals.clear();
            for w in 0..m {
                if membership.is_alive(w) {
                    // A shard-less worker (stripped by capacity-weighted
                    // apportionment, or freshly revived before a boundary
                    // re-plan hands it work back) gets no Work at all — no
                    // roundtrip, no barrier slot — matching the virtual
                    // driver.  On every existing golden/parity trace no
                    // alive worker is ever shard-less, so the legacy
                    // broadcast (and shim realization) sequence is
                    // untouched.
                    if shard_arcs[w].is_empty() {
                        continue;
                    }
                    if topo {
                        // The overlay's dispatched set: every worker a Work
                        // goes out to, downlink fate notwithstanding — the
                        // virtual driver's responder set.
                        topo_responders.push(w);
                    }
                    // Fate events re-realize the roundtrip purely (same key
                    // the shim uses), so they land even when the plan below
                    // suppresses the send.
                    if sink.enabled() {
                        let t = driver_start.elapsed().as_secs_f64();
                        trace::emit_roundtrip_fates(
                            sink,
                            &cluster.net,
                            cluster.seed,
                            w,
                            iter,
                            n_blocks,
                            t,
                        );
                    }
                    // Realize this worker's roundtrip.  A dropped downlink
                    // suppresses the send; otherwise the injected network
                    // latency rides inside the message for the slave to
                    // sleep, so arrival order matches the virtual model.
                    let (plan, reply_delivered) = shim.plan(w, iter);
                    let net_delay = match plan {
                        WorkPlan::Dropped => continue,
                        WorkPlan::Deliver { net_delay } => net_delay,
                    };
                    let shards_w = Arc::clone(&shard_arcs[w]);
                    // Hand back as many recycled buffers as this worker
                    // will need for its per-shard reply payloads.
                    let take = shards_w.len().min(free.len());
                    let recycle: Vec<Vec<f32>> = free.drain(free.len() - take..).collect();
                    if work_txs[w]
                        .send(MasterMsg::Work {
                            iter,
                            theta: Arc::clone(&theta_arc),
                            shards: shards_w,
                            net_delay,
                            compute_scale: elastic.latency_scale(w),
                            recycle,
                        })
                        .is_ok()
                    {
                        dispatched[w] = true;
                        if reply_delivered {
                            deliverable += 1;
                            if topo {
                                topo_scratch.arrivals.push((w, 0.0));
                            }
                        }
                    } else {
                        membership.mark_down(w);
                    }
                }
            }
            if membership.alive() == 0 {
                status = RunStatus::ClusterDead { iter };
                break;
            }
            if topo {
                // Plan the overlay exactly as the virtual driver does —
                // shared code over the same pure inputs, so fold/forward
                // fates, edge accounting, and the trace events match
                // message for message.
                let t = driver_start.elapsed().as_secs_f64();
                crate::agg::plan(
                    &cluster.agg,
                    &cluster.net,
                    cluster.seed,
                    iter,
                    m,
                    &topo_responders,
                    &mut topo_scratch,
                    &mut topo_stats,
                    sink,
                    t,
                );
                // A killed contribution died on an interior edge: account
                // it now (the virtual driver abandons it at drain time) and
                // remember the fate — the physical reply still arrives
                // later and is discarded on receipt.
                for w in 0..m {
                    killed_hist[w].retain(|&k| k + KILL_FATE_HORIZON > iter);
                    if topo_scratch.killed[w] {
                        membership.record_abandoned(w);
                        killed_hist[w].push(iter);
                    }
                }
                deliverable -= topo_scratch.killed_count;
            }
            if deliverable == 0 {
                // Every reply is destined to drop (lossy links or a
                // partition window): nothing can close a barrier, so skip
                // the iteration — the virtual driver burns the same window.
                continue;
            }

            let g_target = match (&cfg.mode, gamma) {
                // With a lossy net, real-mode BSP degrades to closing on
                // whatever replies can still arrive (the virtual driver
                // models Hadoop-style retry instead; see docs/NETWORK.md).
                (SyncMode::Bsp, _) => deliverable,
                // Ring is a collective: every surviving participant is
                // part of the one reduced vector and they all land
                // together — γ shapes nothing inside a ring window.
                (_, Some(_)) if topo_ring => deliverable,
                (_, Some(g)) => g.min(deliverable),
                (mode, None) => {
                    return Err(Error::Config(format!(
                        "mode {} unsupported in real sync driver",
                        mode.name()
                    )))
                }
            };
            let mut barrier = PartialBarrier::new(iter, m, g_target.max(1));
            let mut grads: Vec<(ShardGrad, BlockSet)> = Vec::with_capacity(g_target);
            let mut iter_abandoned = 0usize;
            let mut iter_stale = 0usize;

            // Collect until the barrier closes.
            while !barrier.is_closed() {
                let msg = match res_rx.recv_timeout(STALL_TIMEOUT) {
                    Ok(msg) => msg,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        status = RunStatus::Stalled { iter };
                        break 'iters;
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        status = RunStatus::ClusterDead { iter };
                        break 'iters;
                    }
                };
                match msg {
                    WorkerMsg::Grad {
                        worker,
                        iter: msg_iter,
                        shards,
                        ..
                    } => {
                        let duplicate = match shim.grad_fate(worker, msg_iter) {
                            GradFate::Dropped => continue, // lost in flight
                            GradFate::Deliver { duplicate } => duplicate,
                        };
                        if topo {
                            // Relays deduplicate: the duplicated copy dies
                            // folding into its primary at the first relay
                            // (the virtual drain discards it the same way).
                            if duplicate {
                                membership.record_abandoned(worker);
                            }
                            // Killed at plan time (and accounted there):
                            // discard the physical reply.
                            if killed_hist[worker].contains(&msg_iter) {
                                continue;
                            }
                        }
                        let mut shards = shards;
                        let copies = if topo { 1 } else { 1 + duplicate as usize };
                        for copy in 0..copies {
                            // One Delivery per delivering copy — the virtual
                            // heap materializes the duplicate as its own
                            // arrival, so the journals line up.
                            if sink.enabled() {
                                let t = driver_start.elapsed().as_secs_f64();
                                let deliv = TraceEvent::Delivery { duplicate: copy == 1 };
                                sink.emit(msg_iter, worker as i64, t, deliv);
                            }
                            match barrier.offer(worker, msg_iter) {
                                Admission::Included | Admission::IncludedAndClosed => {
                                    membership.record_contribution(worker);
                                    // Block admission: fold exactly the
                                    // delivered set the virtual transport
                                    // realizes, claiming the blocks so a
                                    // straggling duplicate never re-folds
                                    // one.
                                    let mask = if blocking {
                                        ledger.claim(
                                            worker,
                                            msg_iter,
                                            shim.blocks_for(worker, msg_iter, copy == 1),
                                        )
                                    } else if topo_ring {
                                        // The θ segments this participant
                                        // kept through the collective (full
                                        // under ideal links — the legacy
                                        // whole-vector fold, bit for bit).
                                        topo_scratch.masks[worker]
                                    } else {
                                        BlockSet::full(1)
                                    };
                                    grads.extend(
                                        std::mem::take(&mut shards)
                                            .into_iter()
                                            .map(|sg| (sg, mask)),
                                    );
                                }
                                Admission::Abandoned => {
                                    membership.record_abandoned(worker);
                                    iter_abandoned += 1;
                                }
                                Admission::Stale => {
                                    membership.record_abandoned(worker);
                                    iter_stale += 1;
                                    // Stale-block accounting mirrors the
                                    // virtual reorder path: surviving
                                    // blocks not already folded count as
                                    // stale-admitted.
                                    let mut claimed = 0usize;
                                    if blocking {
                                        let fresh = ledger.claim(
                                            worker,
                                            msg_iter,
                                            shim.blocks_for(worker, msg_iter, copy == 1),
                                        );
                                        stale_blocks_total += fresh.delivered() as u64;
                                        claimed = fresh.delivered() as usize;
                                    }
                                    if sink.enabled() {
                                        let t = driver_start.elapsed().as_secs_f64();
                                        let st =
                                            TraceEvent::StaleAdmission { claimed_blocks: claimed };
                                        sink.emit(msg_iter, worker as i64, t, st);
                                    }
                                }
                            }
                        }
                    }
                    WorkerMsg::SimulatedCrash { worker, .. } => {
                        thread_crashed[worker] = true;
                        membership.mark_down(worker);
                        if sink.enabled() {
                            let t = driver_start.elapsed().as_secs_f64();
                            sink.emit(iter, worker as i64, t, TraceEvent::Crash);
                        }
                        if recovering {
                            if let Some(rollback) = recovery.on_crash(worker, iter, &mut theta) {
                                if sink.enabled() {
                                    let t = driver_start.elapsed().as_secs_f64();
                                    trace::emit_recovery(
                                        sink,
                                        iter,
                                        worker,
                                        t,
                                        recovery.policy().name(),
                                        rollback,
                                    );
                                }
                            }
                        }
                        match (&cfg.mode, cfg.bsp_recovery) {
                            (SyncMode::Bsp, BspRecovery::Stall) => {
                                status = RunStatus::Stalled { iter };
                                break 'iters;
                            }
                            _ => {
                                if membership.alive() == 0 {
                                    status = RunStatus::ClusterDead { iter };
                                    break 'iters;
                                }
                                // This worker's reply will never come
                                // (whether it died on this broadcast or an
                                // older one); if it was counted
                                // deliverable, close on one fewer arrival.
                                // Under an overlay a killed worker was
                                // already subtracted at plan time.
                                if dispatched[worker]
                                    && shim.reply_expected(worker, iter)
                                    && !(topo && topo_scratch.killed[worker])
                                {
                                    deliverable = deliverable.saturating_sub(1);
                                }
                                let new_target = match (&cfg.mode, gamma) {
                                    (SyncMode::Bsp, _) => deliverable,
                                    (_, Some(_)) if topo_ring => deliverable,
                                    (_, Some(g)) => g.min(deliverable),
                                    _ => unreachable!(),
                                };
                                if new_target == 0 && barrier.included() == 0 {
                                    // Nothing arrived and nothing can:
                                    // abandon the iteration entirely.
                                    continue 'iters;
                                }
                                barrier.shrink_gamma(new_target.max(1));
                            }
                        }
                    }
                    WorkerMsg::Fatal { worker, error } => {
                        return Err(Error::Cluster(format!("worker {worker} died: {error}")));
                    }
                }
            }
            if sink.enabled() && !matches!(cfg.mode, SyncMode::Bsp) {
                let t = driver_start.elapsed().as_secs_f64();
                let close = TraceEvent::BarrierClose {
                    gamma: g_target,
                    included: barrier.included(),
                    abandoned: iter_abandoned,
                };
                sink.emit(iter, trace::MASTER, t, close);
            }
            if grads.is_empty() {
                continue;
            }

            // Drain any already-queued stragglers without blocking.  Only
            // replies the network actually delivered count — a dropped
            // reply never reached the coordinator — and, like the collect
            // loop, older-iteration arrivals classify as stale rather than
            // abandoned.
            while let Ok(msg) = res_rx.try_recv() {
                match msg {
                    WorkerMsg::Grad { worker, iter: msg_iter, .. } => {
                        if let GradFate::Deliver { duplicate } = shim.grad_fate(worker, msg_iter)
                        {
                            if topo {
                                // Same dedup/kill discipline as the collect
                                // loop: the duplicate dies at its first
                                // relay, a killed reply was accounted at
                                // plan time.
                                if duplicate {
                                    membership.record_abandoned(worker);
                                }
                                if killed_hist[worker].contains(&msg_iter) {
                                    continue;
                                }
                            }
                            let copies = if topo { 1 } else { 1 + duplicate as usize };
                            membership.record_abandoned(worker);
                            if duplicate && !topo {
                                membership.record_abandoned(worker);
                            }
                            if sink.enabled() {
                                let t = driver_start.elapsed().as_secs_f64();
                                for copy in 0..copies {
                                    let deliv = TraceEvent::Delivery { duplicate: copy == 1 };
                                    sink.emit(msg_iter, worker as i64, t, deliv);
                                }
                            }
                            if msg_iter == iter {
                                iter_abandoned += copies;
                            } else {
                                iter_stale += copies;
                                for copy in 0..copies {
                                    let mut claimed = 0usize;
                                    if blocking {
                                        let fresh = ledger.claim(
                                            worker,
                                            msg_iter,
                                            shim.blocks_for(worker, msg_iter, copy == 1),
                                        );
                                        stale_blocks_total += fresh.delivered() as u64;
                                        claimed = fresh.delivered() as usize;
                                    }
                                    if sink.enabled() {
                                        let t = driver_start.elapsed().as_secs_f64();
                                        let st =
                                            TraceEvent::StaleAdmission { claimed_blocks: claimed };
                                        sink.emit(msg_iter, worker as i64, t, st);
                                    }
                                }
                            }
                        }
                    }
                    WorkerMsg::SimulatedCrash { worker, .. } => {
                        thread_crashed[worker] = true;
                        membership.mark_down(worker);
                        if sink.enabled() {
                            let t = driver_start.elapsed().as_secs_f64();
                            sink.emit(iter, worker as i64, t, TraceEvent::Crash);
                        }
                        if recovering {
                            if let Some(rollback) = recovery.on_crash(worker, iter, &mut theta) {
                                if sink.enabled() {
                                    let t = driver_start.elapsed().as_secs_f64();
                                    trace::emit_recovery(
                                        sink,
                                        iter,
                                        worker,
                                        t,
                                        recovery.policy().name(),
                                        rollback,
                                    );
                                }
                            }
                        }
                    }
                    WorkerMsg::Fatal { worker, error } => {
                        return Err(Error::Cluster(format!("worker {worker} died: {error}")));
                    }
                }
            }

            // Partial recovery: reconstruct a rejoined worker's lost
            // contribution master-side — a fresh compute over its current
            // partition at the current θ, folded with staleness = its
            // downtime.  Appended after the sorted fresh chain, the same
            // fold position the virtual driver uses.
            catchup_grads.clear();
            if recovering {
                recovery.take_catchups(&mut catchups);
                if !catchups.is_empty() {
                    if master_compute.is_none() {
                        master_compute = Some(factory.build(0)?);
                    }
                    let comp = master_compute.as_mut().expect("just built");
                    for c in catchups.iter() {
                        for s in 0..elastic.ownership.owners().len() {
                            if elastic.ownership.owner(s) != c.worker {
                                continue;
                            }
                            let mut out = GradResult::empty();
                            comp.grad_shard_into(s, &theta, iter, &mut out)?;
                            catchup_grads.push((out, c.staleness));
                        }
                    }
                }
            }

            // Aggregate in ascending shard order — the same fold order the
            // virtual simulator uses, so both drivers' f32 sums match.
            grads.sort_by_key(|g| g.0.shard);
            let mut contribs: Vec<Contribution<'_>> = grads
                .iter()
                .map(|(g, mask)| Contribution {
                    grad: &g.grad,
                    examples: g.examples,
                    staleness: 0,
                    blocks: *mask,
                })
                .collect();
            contribs.extend(catchup_grads.iter().map(|(g, stal)| Contribution {
                grad: &g.grad,
                examples: g.examples,
                staleness: *stal,
                blocks: BlockSet::full(1),
            }));
            aggregate(cfg.aggregator, &contribs, &mut agg);
            let grad_norm = vec_ops::norm2(&agg);
            let loss_sum: f64 = grads.iter().filter_map(|(g, _)| g.loss_sum).sum();
            let loss_examples: usize = grads
                .iter()
                .filter(|(g, _)| g.loss_sum.is_some())
                .map(|(g, _)| g.examples)
                .sum();
            let loss = cfg.loss_form.assemble(loss_sum, loss_examples, &theta);
            let included = grads.len();

            // Reclaim the admitted payload buffers for the free-list (they
            // ride back to the slaves in the next Work broadcast).
            drop(contribs);
            for (g, _) in grads.drain(..) {
                free.push(g.grad);
            }
            free.truncate(2 * m);

            opt.step(&mut theta, &agg, iter);
            let now = driver_start.elapsed().as_secs_f64();
            if let Some(sv) = serving.as_mut() {
                sv.on_barrier_close(iter, &theta, sink, now);
            }

            let do_eval = cfg.eval_every > 0 && iter % cfg.eval_every == 0;
            let stop = tracker.observe(iter, loss, grad_norm);
            if (cfg.record_every > 0 && iter % cfg.record_every == 0)
                || do_eval
                || stop.is_some()
            {
                let (eval_loss, theta_err) = if do_eval || stop.is_some() {
                    (hooks.hook_eval_loss(&theta), hooks.hook_theta_err(&theta))
                } else {
                    (None, None)
                };
                let dnet = shim.stats().since(&stats_iter_start);
                rec.push(IterRow {
                    iter,
                    time: now,
                    loss,
                    eval_loss,
                    theta_err,
                    included,
                    abandoned: iter_abandoned,
                    stale: iter_stale,
                    dropped: dnet.dropped as usize,
                    duplicated: dnet.duplicated as usize,
                    blocks: dnet.blocks_delivered as usize,
                    stale_blocks: (stale_blocks_total - stale_blocks_iter_start) as usize,
                    alive: membership.alive(),
                    gamma,
                    grad_norm,
                    recoveries: (recovery.recoveries - recov_iter_start) as usize,
                    rollback_iters: recovery.rollback_iters - rollback_iter_start,
                });
            }
            if let Some(s) = stop {
                status = s;
                break;
            }
        }

        // --- shutdown --------------------------------------------------
        for tx in &work_txs {
            let _ = tx.send(MasterMsg::Shutdown);
        }
        Ok(())
    })?;

    Ok(RunReport {
        recorder: rec,
        theta,
        status,
        gamma,
        mode_name: cfg.mode.name(),
        total_contributions: membership.total_contributed(),
        total_abandoned: membership.total_abandoned(),
        crashes: membership.crashes(),
        rejoins: membership.rejoins(),
        rebalances: elastic.rebalances(),
        shard_owners: elastic.ownership.owners().to_vec(),
        net: shim.stats(),
        agg: topo_stats,
        stale_blocks: stale_blocks_total,
        mean_staleness: None,
        recoveries: recovery.recoveries,
        rollback_iters: recovery.rollback_iters,
        driver_secs: driver_start.elapsed().as_secs_f64(),
        trace: sink.summary(),
        serve: serving.map(crate::serve::ServeEngine::finish),
    })
}

/// Plan one real-async roundtrip: realize worker `w`'s next message fate
/// (keyed by its per-worker attempt counter — the dispatch's *version
/// tag*, the async analogue of the sync drivers' iteration key), account
/// it, and return the injected network latency the slave should sleep.
/// `reply_ok[w]` records whether the master will honor the reply or
/// discard it and retransmit.  Duplicates are counted (`count_dup =
/// true`), matching the virtual async policy's accounting; only the
/// virtual heap materializes the second copy, so no detection path is
/// needed here — one physical reply exists per roundtrip.  With block
/// admission active (`n_blocks > 1`) the reply's delivered set is realized
/// alongside and written to `blocks_out[w]` for the fold to mask.
/// Fate trace events key on the same version tag the realization uses, so
/// they match the virtual async policy's journal message for message.
#[allow(clippy::too_many_arguments)]
fn plan_async_roundtrip(
    net: &crate::net::NetSpec,
    net_ideal: bool,
    seed: u64,
    w: usize,
    attempts: &mut [u64],
    reply_ok: &mut [bool],
    stats: &mut NetStats,
    n_blocks: usize,
    blocks_out: &mut [BlockSet],
    sink: &mut dyn TraceSink,
    driver_start: Instant,
) -> f64 {
    let tag = attempts[w];
    if sink.enabled() {
        let now = driver_start.elapsed().as_secs_f64();
        trace::emit_roundtrip_fates(sink, net, seed, w, tag, n_blocks, now);
    }
    let r = if net_ideal {
        crate::net::LinkRealization::ideal()
    } else {
        net.realize(seed, w, tag)
    };
    attempts[w] += 1;
    reply_ok[w] = if net_ideal {
        let ok = stats.count_roundtrip(&r, true);
        if n_blocks > 1 {
            stats.count_blocks_ideal(n_blocks);
        }
        blocks_out[w] = BlockSet::full(n_blocks);
        ok
    } else if n_blocks > 1 {
        let blocks = net.realize_blocks(seed, w, tag, n_blocks, r.up_dropped, false);
        blocks_out[w] = blocks;
        stats.count_roundtrip_blocks(&r, blocks, net.admits(blocks), true)
    } else {
        stats.count_roundtrip(&r, true)
    };
    r.roundtrip_delay()
}

fn run_real_async(
    cluster: &ClusterSpec,
    cfg: &RunConfig,
    factory: &dyn ComputeFactory,
    hooks: &dyn EvalHooks,
    sink: &mut dyn TraceSink,
    serve: Option<&crate::serve::ServeSpec>,
) -> Result<RunReport> {
    let driver_start = Instant::now();
    let m = factory.workers();
    let dim = factory.dim();
    // Serving engine (None without a [serve] config): the serve clock
    // advances every m-th applied update, the same update-count keying
    // the virtual async policy uses, so both drivers realize one serving
    // history for the same `(seed, schedule)` (docs/SERVING.md).
    let mut serving = serve.map(crate::serve::ServeEngine::new);
    let damping = match cfg.mode {
        SyncMode::Async { damping } => damping,
        _ => unreachable!(),
    };

    let (res_tx, res_rx) = mpsc::channel::<WorkerMsg>();
    let mut work_txs: Vec<mpsc::Sender<MasterMsg>> = Vec::with_capacity(m);
    let mut theta = cfg.init_theta.clone().unwrap_or_else(|| vec![0.0f32; dim]);
    let mut opt = cfg.optimizer.build();
    let mut tracker = ConvergenceTracker::new(cfg.stop.clone());
    let mut rec = Recorder::new();
    let mut membership = Membership::new(m);
    let mut status = RunStatus::Completed;
    let mut version = 0u64;
    let mut version_given = vec![0u64; m];
    let mut staleness_sum = 0.0;
    let mut updates = 0u64;
    let mut scaled = vec![0.0f32; dim];
    let mut loss_ema: Option<f64> = None;
    let net_ideal = cluster.net.is_ideal();
    let mut net_stats = NetStats::default();
    let mut stats_at_row = NetStats::default();
    let mut attempts = vec![0u64; m];
    let mut reply_ok = vec![true; m];
    // Block admission state: the delivered set of each worker's
    // outstanding dispatch, masked into the fold.
    let n_blocks = cluster.net.n_blocks(dim);
    let mut blocks_out = vec![BlockSet::full(n_blocks); m];
    // θ snapshots per dispatch: a lost roundtrip retransmits the *held*
    // snapshot (the virtual driver's worker retries from the θ it already
    // has), never a fresh one.
    let mut theta_ledger = ThetaLedger::new(m);
    // Fresh snapshots recycle through the pool once their slave and the
    // ledger both release them — no per-dispatch θ clone in steady state.
    let mut theta_pool = ThetaPool::new();
    // Elastic membership: ownership + rebalance state shared with the
    // virtual engine; scheduled events land at update-count boundaries
    // (iteration k ≈ update k·M, the sync-iteration equivalent).
    let mut elastic = ElasticRuntime::new(&membership);
    elastic.configure_capacity(
        cluster.capacity_vec(),
        cluster.warmup_iters,
        cluster.weighted_rebalance,
    );
    let mut evicted = vec![false; m];
    let mut thread_crashed = vec![false; m];
    // One Work in flight per alive worker; a Join while the pre-leave
    // reply is still in flight marks it for discard-and-redispatch.
    let mut in_flight = vec![false; m];
    let mut stale_pending = vec![false; m];
    let mut next_boundary = 1u64;

    std::thread::scope(|scope| -> Result<()> {
        let profiles = cluster.profiles();
        // Iteration-0 boundary precedes the opening dispatches.  The
        // warm-up tick mirrors the virtual engine: its boundary handler
        // runs at update-count 0 only when events are due or rebalancing
        // is on.
        let boundary_due_0 =
            cluster.elastic.at(0).next().is_some() || cluster.rebalance_every > 0;
        if boundary_due_0 {
            elastic.tick_warmup();
        }
        for ev in cluster.elastic.at(0) {
            let was_down = !membership.is_alive(ev.worker);
            if apply_master_event(ev, &mut membership, &thread_crashed, 0) {
                evicted[ev.worker] = ev.kind == ElasticKind::Leave;
                if ev.kind == ElasticKind::Join && was_down {
                    elastic.note_join(ev.worker);
                }
            }
        }
        let rebalanced_0 = elastic.maybe_rebalance(0, cluster.rebalance_every, &membership)?;
        if sink.enabled() && boundary_due_0 {
            // Mirror the virtual engine, whose boundary handler runs at
            // update-count 0 only when events are due or rebalancing is on.
            let t = driver_start.elapsed().as_secs_f64();
            let owners = elastic.ownership.owners();
            trace::emit_boundary(sink, &cluster.elastic, 0, rebalanced_0, owners, t);
        }
        // Per-worker shard lists behind `Arc`s, rebuilt only on rebalance —
        // each dispatch clones the handle, not the list.
        let mut shard_arcs: Vec<Arc<Vec<usize>>> =
            elastic.ownership.grouped().into_iter().map(Arc::new).collect();
        for w in 0..m {
            let (tx, rx) = mpsc::channel::<MasterMsg>();
            if !evicted[w] {
                // Kick off the first round immediately.
                let net_delay = plan_async_roundtrip(
                    &cluster.net,
                    net_ideal,
                    cluster.seed,
                    w,
                    &mut attempts,
                    &mut reply_ok,
                    &mut net_stats,
                    n_blocks,
                    &mut blocks_out,
                    sink,
                    driver_start,
                );
                let snap = theta_pool.snapshot(&theta);
                theta_ledger.hold(w, &snap);
                tx.send(MasterMsg::Work {
                    iter: 0,
                    theta: snap,
                    shards: Arc::clone(&shard_arcs[w]),
                    net_delay,
                    compute_scale: elastic.latency_scale(w),
                    recycle: Vec::new(),
                })
                .expect("fresh channel");
                in_flight[w] = true;
            }
            work_txs.push(tx);
            let res_tx = res_tx.clone();
            let profile = profiles[w].clone();
            let seed = cluster.seed;
            scope.spawn(move || {
                slave::worker_main(w, seed, profile, 0, factory, rx, res_tx);
            });
        }
        drop(res_tx);

        while updates < cfg.stop.max_iters {
            // Boundaries due at this update count: scheduled leave/join
            // events and the rebalance cadence, mirroring the virtual
            // engine's boundary handler.
            while next_boundary <= updates / m as u64 {
                let b = next_boundary;
                next_boundary += 1;
                if cluster.elastic.at(b).next().is_none() && cluster.rebalance_every == 0 {
                    continue;
                }
                elastic.tick_warmup();
                for ev in cluster.elastic.at(b) {
                    let was_down = !membership.is_alive(ev.worker);
                    if apply_master_event(ev, &mut membership, &thread_crashed, b) {
                        evicted[ev.worker] = ev.kind == ElasticKind::Leave;
                        if ev.kind == ElasticKind::Join && was_down {
                            elastic.note_join(ev.worker);
                        }
                    }
                }
                let rebalanced = elastic.maybe_rebalance(b, cluster.rebalance_every, &membership)?;
                if rebalanced {
                    for (w, shards) in elastic.ownership.grouped().into_iter().enumerate() {
                        shard_arcs[w] = Arc::new(shards);
                    }
                    log::debug!("async boundary {b}: shard ownership rebalanced");
                }
                if sink.enabled() {
                    let t = driver_start.elapsed().as_secs_f64();
                    let owners = elastic.ownership.owners();
                    trace::emit_boundary(sink, &cluster.elastic, b, rebalanced, owners, t);
                }
                // Re-admitted workers get a fresh θ snapshot (staleness 0)
                // and a new dispatch; a pre-leave reply still in flight is
                // marked for discard so it cannot double-apply.
                for ev in cluster.elastic.at(b) {
                    let w = ev.worker;
                    if ev.kind != ElasticKind::Join || evicted[w] || thread_crashed[w] {
                        continue;
                    }
                    version_given[w] = version;
                    if in_flight[w] {
                        stale_pending[w] = true;
                        continue;
                    }
                    let net_delay = plan_async_roundtrip(
                        &cluster.net,
                        net_ideal,
                        cluster.seed,
                        w,
                        &mut attempts,
                        &mut reply_ok,
                        &mut net_stats,
                        n_blocks,
                        &mut blocks_out,
                        sink,
                        driver_start,
                    );
                    let snap = theta_pool.snapshot(&theta);
                    theta_ledger.hold(w, &snap);
                    let _ = work_txs[w].send(MasterMsg::Work {
                        iter: updates,
                        theta: snap,
                        shards: Arc::clone(&shard_arcs[w]),
                        net_delay,
                        compute_scale: elastic.latency_scale(w),
                        recycle: Vec::new(),
                    });
                    in_flight[w] = true;
                }
            }

            let msg = match res_rx.recv_timeout(STALL_TIMEOUT) {
                Ok(msg) => msg,
                Err(_) => {
                    status = RunStatus::Stalled { iter: updates };
                    break;
                }
            };
            match msg {
                WorkerMsg::Grad { worker, shards, .. } => {
                    in_flight[worker] = false;
                    // One Delivery per delivering roundtrip, keyed on the
                    // dispatch's version tag; a lost roundtrip (reply_ok
                    // false) has none — matching the virtual async heap.
                    if sink.enabled() && reply_ok[worker] {
                        let t = driver_start.elapsed().as_secs_f64();
                        let deliv = TraceEvent::Delivery { duplicate: false };
                        sink.emit(attempts[worker] - 1, worker as i64, t, deliv);
                    }
                    if evicted[worker] {
                        // Reply from before a scheduled leave: discard, do
                        // not reschedule (the worker idles until its join).
                        // The straggler this flag marked has now landed, so
                        // a later rejoin starts clean.
                        stale_pending[worker] = false;
                        membership.record_abandoned(worker);
                        continue;
                    }
                    if stale_pending[worker] {
                        // Pre-leave straggler arriving after the rejoin:
                        // discard and hand the worker fresh parameters.
                        stale_pending[worker] = false;
                        membership.record_abandoned(worker);
                        let net_delay = plan_async_roundtrip(
                            &cluster.net,
                            net_ideal,
                            cluster.seed,
                            worker,
                            &mut attempts,
                            &mut reply_ok,
                            &mut net_stats,
                            n_blocks,
                            &mut blocks_out,
                            sink,
                            driver_start,
                        );
                        version_given[worker] = version;
                        let snap = theta_pool.snapshot(&theta);
                        theta_ledger.hold(worker, &snap);
                        let _ = work_txs[worker].send(MasterMsg::Work {
                            iter: updates,
                            theta: snap,
                            shards: Arc::clone(&shard_arcs[worker]),
                            net_delay,
                            compute_scale: elastic.latency_scale(worker),
                            recycle: shards.into_iter().map(|sg| sg.grad).collect(),
                        });
                        in_flight[worker] = true;
                        continue;
                    }
                    if !reply_ok[worker] {
                        // The network lost this roundtrip (Work down or
                        // reply up): discard and retransmit.  Mirror the
                        // virtual driver, whose worker retries from the θ
                        // it already holds: resend the *held* snapshot and
                        // keep `version_given` — refreshing either here
                        // would silently shrink the eventual reply's
                        // staleness and diverge the drivers.
                        let net_delay = plan_async_roundtrip(
                            &cluster.net,
                            net_ideal,
                            cluster.seed,
                            worker,
                            &mut attempts,
                            &mut reply_ok,
                            &mut net_stats,
                            n_blocks,
                            &mut blocks_out,
                            sink,
                            driver_start,
                        );
                        let held = match theta_ledger.held(worker) {
                            Some(held) => held,
                            None => theta_pool.snapshot(&theta),
                        };
                        let _ = work_txs[worker].send(MasterMsg::Work {
                            iter: updates,
                            theta: held,
                            shards: Arc::clone(&shard_arcs[worker]),
                            net_delay,
                            compute_scale: elastic.latency_scale(worker),
                            recycle: shards.into_iter().map(|sg| sg.grad).collect(),
                        });
                        in_flight[worker] = true;
                        continue;
                    }
                    // Fold the worker's owned shards: the static layout is
                    // a single shard (bit-identical to the historical
                    // copy-through); an elastic multi-shard owner folds a
                    // plain mean in the canonical aggregator order —
                    // unit-weight folds, then one 1/k scale, then the
                    // damping weight — the same f32 op sequence the virtual
                    // async policy uses, so the drivers cannot drift on the
                    // fold arithmetic.
                    let k = shards.len();
                    if k == 0 {
                        // Zero-shard heartbeat under churn: redispatch with
                        // fresh parameters — and account the fresh snapshot,
                        // like every other fresh-θ redispatch, so the next
                        // real reply's staleness counts from here.
                        let net_delay = plan_async_roundtrip(
                            &cluster.net,
                            net_ideal,
                            cluster.seed,
                            worker,
                            &mut attempts,
                            &mut reply_ok,
                            &mut net_stats,
                            n_blocks,
                            &mut blocks_out,
                            sink,
                            driver_start,
                        );
                        version_given[worker] = version;
                        let snap = theta_pool.snapshot(&theta);
                        theta_ledger.hold(worker, &snap);
                        let _ = work_txs[worker].send(MasterMsg::Work {
                            iter: updates,
                            theta: snap,
                            shards: Arc::clone(&shard_arcs[worker]),
                            net_delay,
                            compute_scale: elastic.latency_scale(worker),
                            recycle: Vec::new(),
                        });
                        in_flight[worker] = true;
                        continue;
                    }
                    let staleness = version - version_given[worker];
                    staleness_sum += staleness as f64;
                    membership.record_contribution(worker);
                    let weight = if damping > 0.0 {
                        (1.0 / (1.0 + staleness as f64)).powf(damping) as f32
                    } else {
                        1.0
                    };
                    let mut loss_sum = 0.0f64;
                    let mut any_loss = false;
                    let mut loss_examples = 0usize;
                    if k == 1 {
                        scaled.copy_from_slice(&shards[0].grad);
                    } else {
                        scaled.fill(0.0);
                        for sg in shards.iter() {
                            vec_ops::axpy(1.0, &sg.grad, &mut scaled);
                        }
                        vec_ops::scale(&mut scaled, (1.0 / k as f64) as f32);
                    }
                    if weight != 1.0 {
                        vec_ops::scale(&mut scaled, weight);
                    }
                    // Block admission: zero the ranges of blocks the
                    // network lost, the same masked fold the virtual async
                    // policy applies.  A full set is a no-op.
                    let blocks = blocks_out[worker];
                    if !blocks.is_full() {
                        for b in 0..blocks.len() {
                            if !blocks.contains(b) {
                                let (lo, hi) = blocks.range(b, dim);
                                scaled[lo..hi].fill(0.0);
                            }
                        }
                    }
                    for sg in shards.iter() {
                        if let Some(ls) = sg.loss_sum {
                            loss_sum += ls;
                            any_loss = true;
                        }
                        loss_examples += sg.examples;
                    }
                    opt.step(&mut theta, &scaled, updates);
                    version += 1;
                    updates += 1;
                    if updates % m as u64 == 0 {
                        if let Some(sv) = serving.as_mut() {
                            let now = driver_start.elapsed().as_secs_f64();
                            sv.on_barrier_close(updates / m as u64 - 1, &theta, sink, now);
                        }
                    }
                    version_given[worker] = version;
                    // Recycle the reply's payload buffers with the next Work.
                    let recycle: Vec<Vec<f32>> =
                        shards.into_iter().map(|sg| sg.grad).collect();
                    let net_delay = plan_async_roundtrip(
                        &cluster.net,
                        net_ideal,
                        cluster.seed,
                        worker,
                        &mut attempts,
                        &mut reply_ok,
                        &mut net_stats,
                        n_blocks,
                        &mut blocks_out,
                        sink,
                        driver_start,
                    );
                    let snap = theta_pool.snapshot(&theta);
                    theta_ledger.hold(worker, &snap);
                    let _ = work_txs[worker].send(MasterMsg::Work {
                        iter: updates,
                        theta: snap,
                        shards: Arc::clone(&shard_arcs[worker]),
                        net_delay,
                        compute_scale: elastic.latency_scale(worker),
                        recycle,
                    });
                    in_flight[worker] = true;

                    if any_loss {
                        let shard_loss = cfg.loss_form.assemble(loss_sum, loss_examples, &theta);
                        loss_ema = Some(match loss_ema {
                            None => shard_loss,
                            Some(p) => 0.9 * p + 0.1 * shard_loss,
                        });
                    }
                    let loss = loss_ema.unwrap_or(f64::NAN);
                    let grad_norm = vec_ops::norm2(&scaled);
                    let stop = tracker.observe(updates.saturating_sub(1), loss, grad_norm);
                    if updates % (cfg.record_every.max(1) * m as u64) == 0 || stop.is_some() {
                        let dnet = net_stats.since(&stats_at_row);
                        stats_at_row = net_stats;
                        rec.push(IterRow {
                            iter: updates,
                            time: driver_start.elapsed().as_secs_f64(),
                            loss,
                            eval_loss: hooks.hook_eval_loss(&theta),
                            theta_err: hooks.hook_theta_err(&theta),
                            included: k,
                            abandoned: 0,
                            stale: 0,
                            dropped: dnet.dropped as usize,
                            duplicated: dnet.duplicated as usize,
                            blocks: dnet.blocks_delivered as usize,
                            stale_blocks: 0,
                            alive: membership.alive(),
                            gamma: None,
                            grad_norm,
                            recoveries: 0,
                            rollback_iters: 0,
                        });
                    }
                    if let Some(s) = stop {
                        status = s;
                        break;
                    }
                }
                WorkerMsg::SimulatedCrash { worker, .. } => {
                    thread_crashed[worker] = true;
                    in_flight[worker] = false;
                    membership.mark_down(worker);
                    if sink.enabled() {
                        let t = driver_start.elapsed().as_secs_f64();
                        sink.emit(updates, worker as i64, t, TraceEvent::Crash);
                    }
                    if membership.alive() == 0 {
                        status = RunStatus::ClusterDead { iter: updates };
                        break;
                    }
                }
                WorkerMsg::Fatal { worker, error } => {
                    return Err(Error::Cluster(format!("worker {worker} died: {error}")));
                }
            }
        }
        for tx in &work_txs {
            let _ = tx.send(MasterMsg::Shutdown);
        }
        Ok(())
    })?;

    Ok(RunReport {
        recorder: rec,
        theta,
        status,
        gamma: None,
        mode_name: "async",
        total_contributions: membership.total_contributed(),
        total_abandoned: membership.total_abandoned(),
        crashes: membership.crashes(),
        rejoins: membership.rejoins(),
        rebalances: elastic.rebalances(),
        shard_owners: elastic.ownership.owners().to_vec(),
        net: net_stats,
        agg: crate::agg::AggStats::default(),
        stale_blocks: 0,
        mean_staleness: if updates > 0 {
            Some(staleness_sum / updates as f64)
        } else {
            None
        },
        recoveries: 0,
        rollback_iters: 0,
        driver_secs: driver_start.elapsed().as_secs_f64(),
        trace: sink.summary(),
        serve: serving.map(crate::serve::ServeEngine::finish),
    })
}
