//! Virtual-time delivery for the discrete-event simulator.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

use super::spec::NetSpec;
use super::NetStats;

/// One message popping out of a [`Transport`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Delivery {
    /// Virtual arrival time, relative to the same origin the sends used
    /// (the lockstep sync driver measures from the iteration start).
    pub at: f64,
    pub worker: usize,
    pub iter: u64,
    /// True for the extra copy of a duplicated reply.
    pub duplicate: bool,
}

/// Virtual-time message routing: sends schedule delivery events, polls pop
/// them in arrival order.  The lockstep sync driver routes one roundtrip
/// per responder per iteration: the `Work` broadcast at relative time 0,
/// `compute` seconds of worker time, and the `Grad` reply back; the
/// network realization decides what survives and when it lands.
pub trait Transport {
    /// Route one coordinator→worker→coordinator roundtrip for `iter`.
    /// Surviving deliveries become [`Transport::poll`]-able events.
    fn send_roundtrip(&mut self, worker: usize, iter: u64, compute: f64);
    /// Pop the next delivery in ascending `(time, worker, duplicate)`
    /// order, or `None` when everything in flight has been delivered.
    fn poll(&mut self) -> Option<Delivery>;
    /// Distinct workers with a pending primary (non-duplicate) delivery.
    fn deliverable(&self) -> usize;
    /// Message-level accounting so far.
    fn stats(&self) -> NetStats;
}

/// Heap key ordered by `(time, worker, duplicate)`.  Latencies are finite
/// (the spec validates its distributions produce non-NaN samples), so the
/// `partial_cmp` fallback to `Equal` is never load-bearing.
#[derive(PartialEq)]
struct Key {
    at: f64,
    worker: usize,
    duplicate: bool,
    iter: u64,
}

impl Eq for Key {}

impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Key {
    fn cmp(&self, other: &Self) -> Ordering {
        self.at
            .partial_cmp(&other.at)
            .unwrap_or(Ordering::Equal)
            .then(self.worker.cmp(&other.worker))
            .then(self.duplicate.cmp(&other.duplicate))
    }
}

/// The simulator's [`Transport`]: realizes every message's fate from the
/// pure [`NetSpec::realize`] function and keeps surviving deliveries on a
/// min-heap.  With an ideal spec the fast path schedules delivery exactly
/// at `compute` with no sampling — the pre-transport timing model, bit for
/// bit.
pub struct VirtualTransport {
    spec: NetSpec,
    seed: u64,
    ideal: bool,
    heap: BinaryHeap<Reverse<Key>>,
    primaries: usize,
    stats: NetStats,
}

impl VirtualTransport {
    pub fn new(spec: NetSpec, seed: u64) -> VirtualTransport {
        let ideal = spec.is_ideal();
        VirtualTransport {
            spec,
            seed,
            ideal,
            heap: BinaryHeap::new(),
            primaries: 0,
            stats: NetStats::default(),
        }
    }

    pub fn is_ideal(&self) -> bool {
        self.ideal
    }
}

impl Transport for VirtualTransport {
    fn send_roundtrip(&mut self, worker: usize, iter: u64, compute: f64) {
        if self.ideal {
            self.stats.sent += 2;
            self.stats.delivered += 2;
            self.heap.push(Reverse(Key { at: compute, worker, duplicate: false, iter }));
            self.primaries += 1;
            return;
        }
        let r = self.spec.realize(self.seed, worker, iter);
        if !self.stats.count_roundtrip(&r, true) {
            return;
        }
        let at = r.down_delay + compute + r.up_delay;
        self.heap.push(Reverse(Key { at, worker, duplicate: false, iter }));
        self.primaries += 1;
        if r.up_duplicated {
            self.heap.push(Reverse(Key { at: at + r.dup_lag, worker, duplicate: true, iter }));
        }
    }

    fn poll(&mut self) -> Option<Delivery> {
        self.heap.pop().map(|Reverse(k)| {
            if !k.duplicate {
                self.primaries -= 1;
            }
            Delivery { at: k.at, worker: k.worker, iter: k.iter, duplicate: k.duplicate }
        })
    }

    fn deliverable(&self) -> usize {
        self.primaries
    }

    fn stats(&self) -> NetStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::link::LinkModel;
    use crate::straggler::DelayModel;

    #[test]
    fn ideal_delivers_in_compute_order() {
        let mut t = VirtualTransport::new(NetSpec::ideal(), 1);
        t.send_roundtrip(0, 5, 0.03);
        t.send_roundtrip(1, 5, 0.01);
        t.send_roundtrip(2, 5, 0.02);
        assert_eq!(t.deliverable(), 3);
        let order: Vec<(usize, f64)> = std::iter::from_fn(|| t.poll())
            .map(|d| (d.worker, d.at))
            .collect();
        assert_eq!(order, vec![(1, 0.01), (2, 0.02), (0, 0.03)]);
        assert_eq!(t.deliverable(), 0);
        assert_eq!(t.stats().sent, 6);
        assert_eq!(t.stats().delivered, 6);
    }

    #[test]
    fn ties_break_by_worker_then_duplicate() {
        // dup_prob 1.0 would fail validate(), but realize() is the unit
        // under test here and `next_f64 < 1.0` always holds — so every
        // reply duplicates, deterministically.
        let spec = NetSpec {
            default_link: LinkModel { dup_prob: 1.0, dup_lag: 0.0, ..LinkModel::ideal() },
            ..NetSpec::ideal()
        };
        let mut t = VirtualTransport::new(spec, 3);
        t.send_roundtrip(1, 0, 0.01);
        t.send_roundtrip(0, 0, 0.01);
        let ds: Vec<Delivery> = std::iter::from_fn(|| t.poll()).collect();
        // Every primary precedes its own duplicate, and equal times order
        // by worker index.
        assert_eq!(ds.len(), 4);
        assert_eq!((ds[0].worker, ds[0].duplicate), (0, false));
        assert_eq!((ds[1].worker, ds[1].duplicate), (0, true));
        assert_eq!((ds[2].worker, ds[2].duplicate), (1, false));
        assert_eq!((ds[3].worker, ds[3].duplicate), (1, true));
        assert_eq!(t.stats().duplicated, 2);
    }

    #[test]
    fn drops_never_surface() {
        let mut t = VirtualTransport::new(NetSpec::lossy(0.5), 7);
        let n = 200u64;
        for iter in 0..n {
            t.send_roundtrip(0, iter, 0.01);
        }
        let popped = std::iter::from_fn(|| t.poll()).count() as u64;
        let s = t.stats();
        assert_eq!(s.sent, s.delivered + s.dropped);
        assert!(s.dropped > 0, "nothing dropped at 50%");
        assert!(popped < n, "popped {popped} of {n} at 50% loss");
        // Each popped event is a delivered Grad whose Work also got
        // through; Works may outnumber Grads (up-direction drops).
        assert!(s.delivered >= 2 * popped, "{s:?} vs {popped} pops");
    }

    #[test]
    fn net_delays_shift_arrivals() {
        let spec = NetSpec {
            default_link: LinkModel {
                latency: DelayModel::Constant { secs: 0.005 },
                ..LinkModel::ideal()
            },
            ..NetSpec::ideal()
        };
        let mut t = VirtualTransport::new(spec, 1);
        t.send_roundtrip(0, 0, 0.02);
        let d = t.poll().unwrap();
        assert!((d.at - 0.03).abs() < 1e-12, "at={}", d.at);
        assert!(t.poll().is_none());
    }

    #[test]
    fn deterministic_across_instances() {
        let mk = || {
            let mut t = VirtualTransport::new(NetSpec::lossy(0.3), 11);
            for iter in 0..50 {
                for w in 0..4 {
                    t.send_roundtrip(w, iter, 0.01 * (w + 1) as f64);
                }
            }
            let ds: Vec<Delivery> = std::iter::from_fn(|| t.poll()).collect();
            (ds, t.stats())
        };
        let (d1, s1) = mk();
        let (d2, s2) = mk();
        assert_eq!(d1, d2);
        assert_eq!(s1, s2);
    }
}
