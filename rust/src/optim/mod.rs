//! Master-side optimizers over aggregated gradients.
//!
//! The paper (§1) claims the hybrid barrier applies to "a list of algorithms
//! including iterations such as Stochastic Gradient Descent, Conjugate
//! Gradient Descent, L-BFGS and so on" — T4 validates exactly that by
//! driving the same problem with each of these masters.  All operate on the
//! flat parameter vector; the KRR default (plain SGD with the `η_t/γ`
//! scaling of Algorithm 2) is [`Sgd`].

pub mod adam;
pub mod cg;
pub mod gd;
pub mod lbfgs;
pub mod momentum;

pub use adam::Adam;
pub use cg::ConjugateGradient;
pub use gd::Sgd;
pub use lbfgs::Lbfgs;
pub use momentum::Momentum;

/// A first-order optimizer consuming one aggregated gradient per iteration.
pub trait Optimizer {
    /// Apply one update in place.
    fn step(&mut self, theta: &mut [f32], grad: &[f32], iter: u64);
    /// Human-readable name for reports.
    fn name(&self) -> &'static str;
    /// Reset internal state (used when a run restarts).
    fn reset(&mut self);
}

/// Step-size schedule `η_t = η₀ / (1 + decay·t)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EtaSchedule {
    pub eta0: f64,
    pub decay: f64,
}

impl EtaSchedule {
    pub fn constant(eta0: f64) -> EtaSchedule {
        EtaSchedule { eta0, decay: 0.0 }
    }

    #[inline]
    pub fn at(&self, iter: u64) -> f64 {
        self.eta0 / (1.0 + self.decay * iter as f64)
    }
}

/// Config-friendly optimizer selector.
#[derive(Clone, Debug, PartialEq)]
pub enum OptimizerKind {
    Sgd { eta: EtaSchedule },
    Momentum { eta: EtaSchedule, mu: f64, nesterov: bool },
    Adam { eta: f64, beta1: f64, beta2: f64, eps: f64 },
    Lbfgs { eta: f64, history: usize },
    Cg { eta: f64, restart: usize },
}

impl OptimizerKind {
    pub fn sgd(eta0: f64) -> OptimizerKind {
        OptimizerKind::Sgd {
            eta: EtaSchedule::constant(eta0),
        }
    }

    pub fn build(&self) -> Box<dyn Optimizer + Send> {
        match self {
            OptimizerKind::Sgd { eta } => Box::new(Sgd::new(*eta)),
            OptimizerKind::Momentum { eta, mu, nesterov } => {
                Box::new(Momentum::new(*eta, *mu, *nesterov))
            }
            OptimizerKind::Adam { eta, beta1, beta2, eps } => {
                Box::new(Adam::new(*eta, *beta1, *beta2, *eps))
            }
            OptimizerKind::Lbfgs { eta, history } => Box::new(Lbfgs::new(*eta, *history)),
            OptimizerKind::Cg { eta, restart } => Box::new(ConjugateGradient::new(*eta, *restart)),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OptimizerKind::Sgd { .. } => "sgd",
            OptimizerKind::Momentum { nesterov: false, .. } => "momentum",
            OptimizerKind::Momentum { nesterov: true, .. } => "nesterov",
            OptimizerKind::Adam { .. } => "adam",
            OptimizerKind::Lbfgs { .. } => "lbfgs",
            OptimizerKind::Cg { .. } => "cg",
        }
    }
}

#[cfg(test)]
pub(crate) mod test_util {
    //! Shared optimizer test harness: minimize a known quadratic.

    use super::Optimizer;

    /// Minimize f(x) = 0.5 Σ c_i (x_i − t_i)² from zero; returns final error.
    pub fn run_quadratic(opt: &mut dyn Optimizer, iters: u64) -> f64 {
        let targets = [1.0f32, -2.0, 0.5, 3.0, -0.25, 1.5];
        let curv = [1.0f32, 0.5, 2.0, 0.8, 1.5, 1.0];
        let mut x = vec![0.0f32; targets.len()];
        let mut g = vec![0.0f32; targets.len()];
        for it in 0..iters {
            for i in 0..x.len() {
                g[i] = curv[i] * (x[i] - targets[i]);
            }
            opt.step(&mut x, &g, it);
        }
        x.iter()
            .zip(&targets)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eta_schedule_decays() {
        let s = EtaSchedule { eta0: 1.0, decay: 0.1 };
        assert_eq!(s.at(0), 1.0);
        assert!((s.at(10) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn kinds_build_and_name() {
        for kind in [
            OptimizerKind::sgd(0.1),
            OptimizerKind::Momentum { eta: EtaSchedule::constant(0.1), mu: 0.9, nesterov: false },
            OptimizerKind::Momentum { eta: EtaSchedule::constant(0.1), mu: 0.9, nesterov: true },
            OptimizerKind::Adam { eta: 0.1, beta1: 0.9, beta2: 0.999, eps: 1e-8 },
            OptimizerKind::Lbfgs { eta: 0.5, history: 5 },
            OptimizerKind::Cg { eta: 0.1, restart: 10 },
        ] {
            let opt = kind.build();
            assert_eq!(opt.name(), kind.name());
        }
    }

    #[test]
    fn every_kind_minimizes_quadratic() {
        let kinds = [
            OptimizerKind::sgd(0.5),
            OptimizerKind::Momentum { eta: EtaSchedule::constant(0.2), mu: 0.9, nesterov: false },
            OptimizerKind::Momentum { eta: EtaSchedule::constant(0.2), mu: 0.9, nesterov: true },
            OptimizerKind::Adam { eta: 0.2, beta1: 0.9, beta2: 0.999, eps: 1e-8 },
            OptimizerKind::Lbfgs { eta: 0.5, history: 7 },
            OptimizerKind::Cg { eta: 0.3, restart: 6 },
        ];
        for kind in kinds {
            let mut opt = kind.build();
            let err = test_util::run_quadratic(opt.as_mut(), 300);
            assert!(err < 1e-2, "{} err={err}", kind.name());
        }
    }
}
