//! The master (Algorithm 2): run configuration, run reports, and the
//! threaded real-time coordinator.
//!
//! Two interchangeable drivers share everything in this module:
//!
//! * [`crate::sim::run_virtual`] — discrete-event simulation (deterministic,
//!   fast; the default for benches/experiments);
//! * [`Coordinator::run_real`] — worker OS-threads with real sleeps,
//!   measuring wall-clock (the "production" runtime and the demo of actual
//!   time savings).
//!
//! Both close each iteration with [`barrier::PartialBarrier`], aggregate via
//! [`aggregator`], update via [`crate::optim`], and stop via
//! [`convergence`].

pub mod aggregator;
pub mod barrier;
pub mod convergence;
pub mod estimator;
pub mod modes;

pub use aggregator::AggregatorKind;
pub use convergence::{RunStatus, StopRule};
pub use modes::SyncMode;

use crate::cluster::{ClusterSpec, ElasticKind};
use crate::metrics::Recorder;
use crate::optim::OptimizerKind;
use crate::{Error, Result};

/// Validate a cluster's elastic *and network* configuration against a sync
/// mode.  Shared by both drivers and [`Coordinator::new`], so the
/// compatibility rules cannot drift between virtual and real timing:
///
/// * worker indices must be in range and the schedule must never evict the
///   whole cluster with events still pending ([`crate::cluster::ElasticSchedule::validate`]);
/// * the network spec's probabilities, partition windows, and per-link
///   overrides must be well-formed ([`crate::net::NetSpec::validate`]);
/// * async mode accepts elastic schedules too: it has no barrier
///   iterations, so a scheduled event at iteration `k` lands at the
///   update-count boundary `k·M` (the sync-iteration equivalent — see
///   `docs/SIM.md`);
/// * BSP guarantees every shard contributes every iteration, so scheduled
///   leaves require rebalancing (otherwise the leaver's shards would
///   silently stop contributing — exactly the bias BSP exists to prevent).
pub fn validate_elastic(cluster: &ClusterSpec, mode: &SyncMode) -> Result<()> {
    cluster.elastic.validate(cluster.workers)?;
    cluster.net.validate(cluster.workers)?;
    cluster.agg.validate(cluster.workers, cluster.net.block_size)?;
    let hybrid_family = matches!(
        mode,
        SyncMode::Hybrid { .. } | SyncMode::HybridAuto { .. } | SyncMode::HybridAdaptive { .. }
    );
    if !cluster.agg.is_star() && !hybrid_family {
        return Err(Error::Config(format!(
            "aggregation topology '{}' requires a hybrid-family mode (BSP folds \
             every reply and async applies each gradient as it lands — neither \
             routes through interior aggregators); got '{}'",
            cluster.agg.topology.name(),
            mode.name()
        )));
    }
    for &(w, c) in &cluster.capacities {
        if w >= cluster.workers {
            return Err(Error::Cluster(format!(
                "capacity entry names worker {w} but cluster has {}",
                cluster.workers
            )));
        }
        if !(c > 0.0 && c.is_finite()) {
            return Err(Error::Cluster(format!(
                "capacity of worker {w} must be positive and finite, got {c}"
            )));
        }
    }
    if matches!(mode, SyncMode::Bsp)
        && cluster.rebalance_every == 0
        && cluster
            .elastic
            .events()
            .iter()
            .any(|e| e.kind == ElasticKind::Leave)
    {
        return Err(Error::Config(
            "BSP with scheduled leaves requires rebalance_every > 0 \
             (every shard must keep a live owner)"
                .into(),
        ));
    }
    Ok(())
}

/// How per-shard loss sums assemble into the reported training loss.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LossForm {
    /// Multiply the per-example average by this (KRR objective carries ½).
    pub scale: f64,
    /// Ridge term `+ ½·λ·‖θ‖²` (0 for the LM).
    pub lambda: f64,
}

impl LossForm {
    pub fn krr(lambda: f64) -> LossForm {
        LossForm { scale: 0.5, lambda }
    }

    pub fn plain() -> LossForm {
        LossForm { scale: 1.0, lambda: 0.0 }
    }

    /// Assemble: `scale · (Σ loss_sum / Σ examples) + ½λ‖θ‖²`.
    pub fn assemble(&self, loss_sum: f64, examples: usize, theta: &[f32]) -> f64 {
        let data = if examples > 0 {
            self.scale * loss_sum / examples as f64
        } else {
            f64::NAN
        };
        let reg = if self.lambda != 0.0 {
            0.5 * self.lambda * crate::math::vec_ops::dot(theta, theta)
        } else {
            0.0
        };
        data + reg
    }
}

/// What BSP does when a worker fails (the paper's "traditional solutions
/// have to calculate it again when failure occurs").
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BspRecovery {
    /// No recovery protocol: the barrier never closes → run reports
    /// [`RunStatus::Stalled`].  (The fault-tolerance contrast case.)
    Stall,
    /// Hadoop-style: detect after `detect_timeout` (virtual seconds),
    /// re-execute the missing shard on a healthy node (permanently
    /// reassigning it if the owner crashed for good).
    Retry { detect_timeout: f64 },
}

/// One experiment run's configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub mode: SyncMode,
    pub optimizer: OptimizerKind,
    pub aggregator: AggregatorKind,
    pub stop: StopRule,
    pub loss_form: LossForm,
    pub bsp_recovery: BspRecovery,
    /// Evaluate the eval hooks every k iterations (0 = only at the end).
    pub eval_every: u64,
    /// Record an [`crate::metrics::IterRow`] every k iterations.
    pub record_every: u64,
    /// Initial parameters (None = zeros).
    pub init_theta: Option<Vec<f32>>,
    /// Adaptive-γ re-estimation window (iterations), for HybridAdaptive.
    pub seed: u64,
    /// What the run does when a worker crashes or leaves
    /// (`[recovery]` config section; see `docs/RECOVERY.md`).
    pub recovery: crate::recovery::RecoveryConfig,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            mode: SyncMode::Bsp,
            optimizer: OptimizerKind::sgd(0.5),
            aggregator: AggregatorKind::Mean,
            stop: StopRule::default(),
            loss_form: LossForm::krr(0.01),
            bsp_recovery: BspRecovery::Retry { detect_timeout: 0.05 },
            eval_every: 10,
            record_every: 1,
            init_theta: None,
            seed: 1,
            recovery: crate::recovery::RecoveryConfig::default(),
        }
    }
}

impl RunConfig {
    pub fn with_mode(mut self, mode: SyncMode) -> Self {
        self.mode = mode;
        self
    }

    pub fn with_iters(mut self, iters: u64) -> Self {
        self.stop.max_iters = iters;
        self
    }
}

/// Everything a finished run reports.
#[derive(Debug)]
pub struct RunReport {
    pub recorder: Recorder,
    pub theta: Vec<f32>,
    pub status: RunStatus,
    /// γ in effect at the end (None for BSP/async).
    pub gamma: Option<usize>,
    pub mode_name: &'static str,
    /// Totals from membership accounting.
    pub total_contributions: u64,
    pub total_abandoned: u64,
    pub crashes: u64,
    /// Workers re-admitted (scheduled joins + supervisor rejoins).
    pub rejoins: u64,
    /// Elastic shard-rebalance plans executed (0 = static membership).
    pub rebalances: u64,
    /// Final shard ownership (index = shard, value = owner) — the state
    /// the elastic runtime ended the run with, for ownership-timeline
    /// assertions in the cross-driver parity suite.
    pub shard_owners: Vec<usize>,
    /// Network-level message accounting.  `dropped`/`duplicated` are zero
    /// under an ideal net; `sent`/`delivered` still count the traffic.
    pub net: crate::net::NetStats,
    /// Aggregation-overlay accounting (interior folds, per-edge hop fates).
    /// All-zero under the default star topology, which has no interior
    /// edges (see [`crate::agg::AggStats`]).
    pub agg: crate::agg::AggStats,
    /// Gradient blocks admitted *stale* — surviving blocks of a straggling
    /// reply that landed in a later window and was folded (or at least
    /// accounted) via the cross-iteration reordering path.  Zero unless
    /// block admission chunks replies (`NetSpec::block_size`) under a
    /// non-ideal net.
    pub stale_blocks: u64,
    /// Async only: mean staleness of applied gradients.
    pub mean_staleness: Option<f64>,
    /// Recovery-policy actions fired (restores, lost-partition
    /// reconstructions, forced replans); 0 under the default
    /// [`crate::recovery::RecoveryPolicy::Abandon`].
    pub recoveries: u64,
    /// Total iterations of progress rolled back by checkpoint restores.
    pub rollback_iters: u64,
    /// Wall-clock of the driver itself (not virtual time), seconds.
    pub driver_secs: f64,
    /// Flight-recorder roll-up (per-worker lanes, latency/abandonment
    /// histograms) when the run was traced through a
    /// [`crate::trace::JournalSink`]; `None` with the default `NoopSink`.
    pub trace: Option<crate::trace::TraceSummary>,
    /// Serving-side rollup (offered/admitted/shed, read/update latency
    /// quantiles, θ staleness at read) when the run served traffic
    /// through [`crate::runner::Runner::serve`]; `None` otherwise.
    pub serve: Option<crate::serve::ServeStats>,
}

impl RunReport {
    pub fn final_loss(&self) -> f64 {
        self.recorder.final_loss()
    }

    pub fn total_time(&self) -> f64 {
        self.recorder.total_time()
    }

    pub fn final_theta_err(&self) -> Option<f64> {
        self.recorder.rows().iter().rev().find_map(|r| r.theta_err)
    }

    /// Abandon rate over the whole run: abandoned / (abandoned+contributed).
    pub fn abandon_rate(&self) -> f64 {
        let total = self.total_abandoned + self.total_contributions;
        if total == 0 {
            0.0
        } else {
            self.total_abandoned as f64 / total as f64
        }
    }

    // --- uniform sub-stat accessors ------------------------------------
    //
    // Every subsystem rollup is reachable as `Option<&T>` — `Some` iff
    // the subsystem was actually exercised this run — so callers probe
    // them all the same way regardless of whether the underlying field
    // is optional (`trace`, `serve`) or always-present accounting
    // (`net`, `agg`, recovery counters).  The raw fields stay public for
    // the oracles that want zeros explicitly.

    /// Network accounting, when any message was sent.
    pub fn net_stats(&self) -> Option<&crate::net::NetStats> {
        (self.net.sent > 0).then_some(&self.net)
    }

    /// Aggregation-overlay accounting, when a non-trivial overlay ran
    /// (interior edges or folds happened).
    pub fn agg_stats(&self) -> Option<&crate::agg::AggStats> {
        (self.agg.edge_sent > 0 || self.agg.folds > 0).then_some(&self.agg)
    }

    /// Flight-recorder roll-up, when the run was journaled.
    pub fn trace_summary(&self) -> Option<&crate::trace::TraceSummary> {
        self.trace.as_ref()
    }

    /// `(recoveries, rollback_iters)`, when a recovery policy fired.
    pub fn recovery_stats(&self) -> Option<(u64, u64)> {
        (self.recoveries > 0).then_some((self.recoveries, self.rollback_iters))
    }

    /// Serving rollup, when the run served traffic (Runner::serve).
    pub fn serve_stats(&self) -> Option<&crate::serve::ServeStats> {
        self.serve.as_ref()
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "[{}] status={:?} iters={} time={:.3}s loss={:.6} theta_err={} abandon={:.1}% crashes={}",
            self.mode_name,
            self.status,
            self.recorder.len(),
            self.total_time(),
            self.final_loss(),
            self.final_theta_err()
                .map(|e| format!("{e:.3e}"))
                .unwrap_or_else(|| "-".into()),
            self.abandon_rate() * 100.0,
            self.crashes,
        );
        if self.net.dropped > 0 || self.net.duplicated > 0 {
            s.push_str(&format!(
                " net_drop={:.1}% net_dup={}",
                self.net.drop_rate() * 100.0,
                self.net.duplicated
            ));
        }
        if self.net.blocks_sent > 0 {
            s.push_str(&format!(
                " blocks={}/{} stale_blocks={}",
                self.net.blocks_delivered, self.net.blocks_sent, self.stale_blocks
            ));
        }
        if self.recoveries > 0 {
            s.push_str(&format!(
                " recoveries={} rollback_iters={}",
                self.recoveries, self.rollback_iters
            ));
        }
        if self.agg.edge_sent > 0 {
            s.push_str(&format!(
                " agg={} folds={} edges={}/{} lost={}",
                self.agg.topology,
                self.agg.folds,
                self.agg.edge_delivered,
                self.agg.edge_sent,
                self.agg.lost_contributions
            ));
        }
        if let Some(sv) = self.serve_stats() {
            s.push_str(&format!(
                " serve_offered={} shed={:.1}% read_p99={:.2}ms stale_p99={:.2}",
                sv.offered,
                sv.shed_rate() * 100.0,
                sv.read_p99_ms,
                sv.staleness_p99
            ));
        }
        s
    }
}

/// The threaded real-time coordinator (see [`crate::worker`] for the slave
/// side).  Construction validates the cluster/mode combination; `run_real`
/// consumes compute factories so each worker thread can build its own
/// non-`Send` PJRT engine.
pub struct Coordinator {
    pub cluster: ClusterSpec,
    pub cfg: RunConfig,
}

impl Coordinator {
    pub fn new(cluster: ClusterSpec, cfg: RunConfig) -> Result<Coordinator> {
        if cluster.workers == 0 {
            return Err(Error::Cluster("cluster needs at least one worker".into()));
        }
        validate_elastic(&cluster, &cfg.mode)?;
        cfg.recovery.validate()?;
        if cfg.mode.is_async()
            && !matches!(cfg.recovery.policy, crate::recovery::RecoveryPolicy::Abandon)
        {
            return Err(Error::Config(format!(
                "recovery policy '{}' is not supported in async mode (async has \
                 no crash/rejoin barrier to recover at); use 'abandon'",
                cfg.recovery.policy.name()
            )));
        }
        if let SyncMode::Hybrid { gamma } = cfg.mode {
            if gamma == 0 || gamma > cluster.workers {
                return Err(Error::Cluster(format!(
                    "gamma {gamma} invalid for {} workers",
                    cluster.workers
                )));
            }
        }
        Ok(Coordinator { cluster, cfg })
    }

    /// Run with real worker threads; implementation lives in
    /// [`crate::worker::run_real`].
    ///
    /// Deprecated entry point: prefer [`crate::runner::Runner`] with
    /// [`crate::runner::Driver::Threaded`]. This thin wrapper is kept so
    /// existing parity/golden call sites stay byte-stable; serving mode
    /// is only exposed through `Runner`.
    pub fn run_real(
        &self,
        factory: &dyn crate::worker::ComputeFactory,
        hooks: &dyn crate::sim::EvalHooks,
    ) -> Result<RunReport> {
        crate::worker::run_real(&self.cluster, &self.cfg, factory, hooks)
    }

    /// [`Coordinator::run_real`] with a flight-recorder sink attached; see
    /// `docs/OBSERVABILITY.md`.
    ///
    /// Deprecated entry point: prefer [`crate::runner::Runner`] with
    /// [`crate::runner::Runner::trace`] attached; see
    /// [`Coordinator::run_real`].
    pub fn run_real_traced(
        &self,
        factory: &dyn crate::worker::ComputeFactory,
        hooks: &dyn crate::sim::EvalHooks,
        sink: &mut dyn crate::trace::TraceSink,
    ) -> Result<RunReport> {
        crate::worker::run_real_traced(&self.cluster, &self.cfg, factory, hooks, sink)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_form_krr() {
        let f = LossForm::krr(0.0);
        let theta = vec![0.0f32; 2];
        // 0.5 * 10/5 = 1.0
        assert!((f.assemble(10.0, 5, &theta) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn loss_form_reg_term() {
        let f = LossForm::krr(2.0);
        let theta = vec![1.0f32, 1.0];
        // 0.5*0 + 0.5*2*2 = 2
        assert!((f.assemble(0.0, 5, &theta) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn coordinator_validates_gamma() {
        let cluster = ClusterSpec {
            workers: 4,
            ..ClusterSpec::default()
        };
        let cfg = RunConfig::default().with_mode(SyncMode::Hybrid { gamma: 5 });
        assert!(Coordinator::new(cluster.clone(), cfg).is_err());
        let ok = RunConfig::default().with_mode(SyncMode::Hybrid { gamma: 4 });
        assert!(Coordinator::new(cluster, ok).is_ok());
    }

    #[test]
    fn validate_elastic_rules() {
        use crate::cluster::ElasticSchedule;
        let churn = ElasticSchedule::crash_and_rejoin(&[1], 5, 10);
        let base = ClusterSpec { workers: 4, ..ClusterSpec::default() };

        // BSP + scheduled leaves needs rebalancing on.
        let c = base.clone().with_elastic(churn.clone(), 0);
        assert!(validate_elastic(&c, &SyncMode::Bsp).is_err());
        let c = base.clone().with_elastic(churn.clone(), 1);
        assert!(validate_elastic(&c, &SyncMode::Bsp).is_ok());

        // Hybrid tolerates orphaned shards (abandonment is its model).
        let c = base.clone().with_elastic(churn.clone(), 0);
        assert!(validate_elastic(&c, &SyncMode::Hybrid { gamma: 2 }).is_ok());

        // Async accepts elastic config: joins/leaves land at update-count
        // boundaries in the unified event engine.
        let c = base.clone().with_elastic(churn, 1);
        assert!(validate_elastic(&c, &SyncMode::Async { damping: 0.0 }).is_ok());
        let c = base.clone().with_elastic(ElasticSchedule::default(), 1);
        assert!(validate_elastic(&c, &SyncMode::Async { damping: 0.0 }).is_ok());
        assert!(validate_elastic(&base, &SyncMode::Async { damping: 0.0 }).is_ok());
        // …but the schedule itself is still validated.
        let bad = ElasticSchedule::parse("9:leave@1").unwrap();
        let c = base.clone().with_elastic(bad, 1);
        assert!(validate_elastic(&c, &SyncMode::Async { damping: 0.0 }).is_err());
    }

    #[test]
    fn report_abandon_rate() {
        let rep = RunReport {
            recorder: Recorder::new(),
            theta: vec![],
            status: RunStatus::Completed,
            gamma: Some(3),
            mode_name: "hybrid",
            total_contributions: 75,
            total_abandoned: 25,
            crashes: 0,
            rejoins: 0,
            rebalances: 0,
            shard_owners: vec![],
            net: crate::net::NetStats::default(),
            agg: crate::agg::AggStats::default(),
            stale_blocks: 0,
            mean_staleness: None,
            recoveries: 0,
            rollback_iters: 0,
            driver_secs: 0.0,
            trace: None,
            serve: None,
        };
        assert!((rep.abandon_rate() - 0.25).abs() < 1e-12);
        assert!(!rep.summary().contains("recoveries="));
    }

    #[test]
    fn coordinator_rejects_async_plus_recovery() {
        use crate::recovery::{RecoveryConfig, RecoveryPolicy};
        let cluster = ClusterSpec { workers: 4, ..ClusterSpec::default() };
        let mut cfg = RunConfig::default().with_mode(SyncMode::Async { damping: 0.0 });
        cfg.recovery =
            RecoveryConfig { policy: RecoveryPolicy::PartialRecovery, ..Default::default() };
        assert!(Coordinator::new(cluster.clone(), cfg.clone()).is_err());
        cfg.recovery = RecoveryConfig::default();
        assert!(Coordinator::new(cluster, cfg).is_ok());
    }
}
