//! PJRT runtime: load AOT HLO-text artifacts and execute them natively.
//!
//! This is the only module that touches the `xla` crate.  The interchange
//! contract with `python/compile/aot.py`:
//!
//! * artifacts are HLO **text** (`*.hlo.txt`) — serialized protos from
//!   jax ≥ 0.5 carry 64-bit instruction ids that xla_extension 0.5.1
//!   rejects, text re-parses cleanly;
//! * every entry point was lowered with `return_tuple=True`, so execution
//!   returns a single tuple literal that [`Executable::run`] decomposes;
//! * `artifacts/manifest.json` records each artifact's input/output
//!   shapes+dtypes, parsed by [`manifest`] and validated on load.
//!
//! **Thread model**: `xla::PjRtClient` is `Rc`-based (not `Send`), so each
//! worker thread builds its own [`Engine`].  In virtual-timing mode a single
//! engine on the driver thread serves all simulated workers.

pub mod engine;
pub mod literal;
pub mod manifest;

pub use engine::{Engine, Executable};
pub use manifest::{ArtifactInfo, Manifest, TensorSpec};

use std::path::{Path, PathBuf};

use crate::{Error, Result};

/// An artifact directory + its parsed manifest: the handle everything else
/// uses to load executables by name.
pub struct ArtifactSet {
    dir: PathBuf,
    manifest: Manifest,
}

impl ArtifactSet {
    /// Open `dir` (usually `artifacts/`) and parse its manifest.
    pub fn open(dir: impl AsRef<Path>) -> Result<ArtifactSet> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        if !manifest_path.exists() {
            return Err(Error::Manifest(format!(
                "{} not found — run `make artifacts` first",
                manifest_path.display()
            )));
        }
        let manifest = Manifest::load(&manifest_path)?;
        Ok(ArtifactSet { dir, manifest })
    }

    /// Locate the repo's `artifacts/` directory: `$HYBRIDITER_ARTIFACTS`,
    /// else `./artifacts`, else `../artifacts` (for tests running deeper).
    pub fn discover() -> Result<ArtifactSet> {
        if let Ok(dir) = std::env::var("HYBRIDITER_ARTIFACTS") {
            return ArtifactSet::open(dir);
        }
        for cand in ["artifacts", "../artifacts", "../../artifacts"] {
            if Path::new(cand).join("manifest.json").exists() {
                return ArtifactSet::open(cand);
            }
        }
        Err(Error::Manifest(
            "artifacts/manifest.json not found (run `make artifacts` or set HYBRIDITER_ARTIFACTS)"
                .into(),
        ))
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn info(&self, name: &str) -> Result<&ArtifactInfo> {
        self.manifest.get(name)
    }

    /// Full path of an artifact's HLO file.
    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.info(name)?.file))
    }

    /// Compile an artifact on the given engine.
    pub fn load(&self, engine: &Engine, name: &str) -> Result<Executable> {
        let info = self.info(name)?.clone();
        let path = self.dir.join(&info.file);
        engine.compile_hlo_file(&path, info)
    }
}
