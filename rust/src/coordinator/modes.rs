//! Synchronization modes: the policy axis the paper explores.

use crate::coordinator::estimator::{estimate_gamma, EstimatorParams};
use crate::{Error, Result};

/// How the master closes each iteration's barrier.
#[derive(Clone, Debug, PartialEq)]
pub enum SyncMode {
    /// Bulk-synchronous: wait for every alive worker (the Hadoop/Spark
    /// baseline the paper argues against).
    Bsp,
    /// The paper's contribution: wait for the first `gamma` results and
    /// abandon the rest (Algorithm 2).
    Hybrid { gamma: usize },
    /// Hybrid with `gamma` derived from Algorithm 1 at startup:
    /// `γ = ⌈N·u²/( (ξ²N + u²)·ζ )⌉` for confidence `1-α`, relative error ξ.
    HybridAuto { alpha: f64, xi: f64 },
    /// Ablation (DESIGN.md §6): like `HybridAuto`, but γ is re-estimated
    /// every `window` iterations from the *observed* gradient variance
    /// rather than the worst-case bound.
    HybridAdaptive { alpha: f64, xi: f64, window: u64 },
    /// Fully asynchronous parameter-server baseline: apply every gradient
    /// the moment it arrives; `damping` scales stale gradients by
    /// `1/(1+staleness)^damping` (0 = plain async).
    Async { damping: f64 },
}

impl SyncMode {
    /// Resolve the γ in effect at startup (None for BSP/async; adaptive
    /// starts from the Algorithm-1 value).
    pub fn initial_gamma(&self, n_total: usize, zeta: usize, m: usize) -> Result<Option<usize>> {
        match self {
            SyncMode::Bsp | SyncMode::Async { .. } => Ok(None),
            SyncMode::Hybrid { gamma } => {
                if *gamma == 0 || *gamma > m {
                    return Err(Error::Config(format!(
                        "hybrid gamma {gamma} out of range 1..={m}"
                    )));
                }
                Ok(Some(*gamma))
            }
            SyncMode::HybridAuto { alpha, xi } | SyncMode::HybridAdaptive { alpha, xi, .. } => {
                let p = EstimatorParams {
                    alpha: *alpha,
                    xi: *xi,
                };
                Ok(Some(estimate_gamma(n_total, zeta, m, p)?))
            }
        }
    }

    pub fn is_async(&self) -> bool {
        matches!(self, SyncMode::Async { .. })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SyncMode::Bsp => "bsp",
            SyncMode::Hybrid { .. } => "hybrid",
            SyncMode::HybridAuto { .. } => "hybrid-auto",
            SyncMode::HybridAdaptive { .. } => "hybrid-adaptive",
            SyncMode::Async { .. } => "async",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bsp_has_no_gamma() {
        assert_eq!(SyncMode::Bsp.initial_gamma(1000, 100, 10).unwrap(), None);
    }

    #[test]
    fn fixed_gamma_validated() {
        assert_eq!(
            SyncMode::Hybrid { gamma: 3 }.initial_gamma(1000, 100, 10).unwrap(),
            Some(3)
        );
        assert!(SyncMode::Hybrid { gamma: 0 }.initial_gamma(1000, 100, 10).is_err());
        assert!(SyncMode::Hybrid { gamma: 11 }.initial_gamma(1000, 100, 10).is_err());
    }

    #[test]
    fn auto_gamma_uses_estimator() {
        let g = SyncMode::HybridAuto { alpha: 0.05, xi: 0.05 }
            .initial_gamma(32768, 2048, 16)
            .unwrap()
            .unwrap();
        assert!(g >= 1 && g <= 16);
    }

    #[test]
    fn names() {
        assert_eq!(SyncMode::Bsp.name(), "bsp");
        assert_eq!(SyncMode::Async { damping: 0.0 }.name(), "async");
    }
}
