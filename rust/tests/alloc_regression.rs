//! Allocation-regression guard for the perf pass (dedicated test binary —
//! the counting `#[global_allocator]` must own the whole process).
//!
//! *Virtual driver*: after warmup, a steady-state iteration of
//! `sim::run_virtual_traced` with tracing disabled (`NoopSink`) must
//! perform **zero** heap allocations — the `IterScratch` arena, the fused
//! `grad_into` kernel, and the reusable barrier/transport buffers leave
//! nothing to allocate, and the flight recorder's off switch adds nothing.  Measured
//! differentially: two identical runs that differ only in iteration count
//! must allocate exactly the same number of times (setup + warmup
//! allocations cancel; any per-iteration allocation shows up multiplied by
//! the extra iterations).
//!
//! *Threaded runtime*: real channels allocate per message by construction
//! (mpsc nodes, per-broadcast θ Arc), so the guard there is a *budget*:
//! the per-iteration delta must stay small and flat — the gradient-buffer
//! free-list keeps reply payloads out of the allocator.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use hybriditer::cluster::ClusterSpec;
use hybriditer::coordinator::{Coordinator, LossForm, RunConfig, SyncMode};
use hybriditer::data::{KrrProblem, KrrProblemSpec};
use hybriditer::optim::OptimizerKind;
use hybriditer::sim::{self, NoEval};
use hybriditer::straggler::DelayModel;
use hybriditer::trace::{NoopSink, TraceSink};
use hybriditer::worker::NativeKrrFactory;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

fn problem() -> KrrProblem {
    let spec = KrrProblemSpec {
        config: "alloc".into(),
        d: 4,
        l: 16,
        zeta: 64,
        machines: 4,
        noise: 0.05,
        lambda: 0.01,
        bandwidth: 1.0,
        eval_rows: 32,
        seed: 23,
    };
    KrrProblem::generate(&spec).unwrap()
}

fn virtual_run_allocs(p: &KrrProblem, mode: SyncMode, iters: u64, sink: &mut dyn TraceSink) -> u64 {
    let cluster = ClusterSpec {
        workers: 4,
        delay: DelayModel::LogNormal { mu: -5.0, sigma: 1.0 },
        seed: 7,
        ..ClusterSpec::default()
    };
    // record_every/eval_every = 0: recording rows is the one legitimate
    // (caller-requested) allocation a steady-state iteration may make.
    let cfg = RunConfig {
        mode,
        optimizer: OptimizerKind::sgd(0.8),
        loss_form: LossForm::krr(p.spec.lambda),
        eval_every: 0,
        record_every: 0,
        ..RunConfig::default()
    }
    .with_iters(iters);
    let mut pool = p.native_pool();
    let before = allocs();
    let rep = sim::run_virtual_traced(&mut pool, &cluster, &cfg, &NoEval, sink).unwrap();
    let after = allocs();
    assert!(rep.status.is_healthy(), "{:?}", rep.status);
    after - before
}

fn real_run_allocs(p: &KrrProblem, mode: SyncMode, iters: u64) -> u64 {
    let cluster = ClusterSpec {
        workers: 4,
        base_compute: 0.0,
        master_overhead: 0.0,
        seed: 7,
        ..ClusterSpec::default()
    };
    let cfg = RunConfig {
        mode,
        optimizer: OptimizerKind::sgd(0.8),
        loss_form: LossForm::krr(p.spec.lambda),
        eval_every: 0,
        record_every: 0,
        ..RunConfig::default()
    }
    .with_iters(iters);
    let coord = Coordinator::new(cluster, cfg).unwrap();
    let factory = NativeKrrFactory::for_problem(p);
    let before = allocs();
    let rep = coord.run_real(&factory, &NoEval).unwrap();
    let after = allocs();
    assert!(rep.status.is_healthy(), "{:?}", rep.status);
    after - before
}

/// One test drives both checks so the global counter is never shared by
/// concurrently running tests.
#[test]
fn steady_state_allocation_budgets() {
    let p = problem();

    // --- virtual driver: zero allocations per steady-state iteration ---
    // Warm the arena's high-water marks once, then measure differentially.
    // The runs go through the *traced* entry point with tracing disabled
    // (`NoopSink`): the flight recorder's off switch must keep the hot
    // path allocation-free, not just "cheap".
    let hybrid = SyncMode::Hybrid { gamma: 3 };
    let _ = virtual_run_allocs(&p, hybrid.clone(), 50, &mut NoopSink);
    let short = virtual_run_allocs(&p, hybrid.clone(), 100, &mut NoopSink);
    let long = virtual_run_allocs(&p, hybrid, 400, &mut NoopSink);
    assert_eq!(
        long, short,
        "virtual driver allocates per iteration with tracing disabled: {} \
         allocs over 300 extra iterations ({:.2}/iter)",
        long - short,
        (long - short) as f64 / 300.0
    );

    // --- virtual async: near-zero per-update budget --------------------
    // The reschedule path reuses every buffer (`shards_given` capacity,
    // the gradient slots, the damping scratch), so the only steady-state
    // allocations left are the recorder's amortized row-Vec growth (async
    // records every m updates even at record_every = 0) and heap
    // resizing — a handful over thousands of updates, not one per update.
    let a = SyncMode::Async { damping: 0.0 };
    let _ = virtual_run_allocs(&p, a.clone(), 200, &mut NoopSink);
    let short = virtual_run_allocs(&p, a.clone(), 400, &mut NoopSink);
    let long = virtual_run_allocs(&p, a.clone(), 1600, &mut NoopSink);
    let per_update = (long.saturating_sub(short)) as f64 / 1200.0;
    assert!(
        per_update < 0.1,
        "virtual async reschedule path allocates per update: {per_update:.3}/update"
    );

    // --- threaded runtime: small, flat per-iteration budget ------------
    // Channels/Arcs allocate per message by construction; the θ snapshot
    // pool and per-worker shard-list Arcs take the per-broadcast clones
    // out, and the free-list keeps reply payloads out, so the budget is
    // tight: well under 35 allocations per worker-iteration for m = 4.
    let hybrid = SyncMode::Hybrid { gamma: 4 };
    let _ = real_run_allocs(&p, hybrid.clone(), 20);
    let short = real_run_allocs(&p, hybrid.clone(), 40);
    let long = real_run_allocs(&p, hybrid, 120);
    let per_iter = (long.saturating_sub(short)) as f64 / 80.0;
    assert!(
        per_iter < 140.0,
        "threaded runtime allocation budget blown: {per_iter:.1} allocs/iter"
    );

    // --- threaded async: per-update budget ------------------------------
    // One update = one reply in + one dispatch out; the snapshot pool's
    // slots settle near one per worker (the ledger holds one each), after
    // which rescheduling recycles Arcs instead of cloning θ.
    let a = SyncMode::Async { damping: 0.0 };
    let _ = real_run_allocs(&p, a.clone(), 80);
    let short = real_run_allocs(&p, a.clone(), 160);
    let long = real_run_allocs(&p, a, 480);
    let per_update = (long.saturating_sub(short)) as f64 / 320.0;
    assert!(
        per_update < 60.0,
        "threaded async allocation budget blown: {per_update:.1} allocs/update"
    );

    // --- ThetaCell: zero-allocation steady state -----------------------
    // A read is lock + Arc refcount bump; a publish rewrites the retired
    // slot in place once its readers have dropped (`Arc::get_mut`), so
    // after the two slots warm up, neither side may touch the allocator.
    // Measured absolutely, not differentially: the budget is exactly 0.
    let dim = 512;
    let cell = hybriditer::serve::ThetaCell::new(dim);
    let theta = vec![1.0f32; dim];
    cell.publish(&theta, 1);
    cell.publish(&theta, 2);
    let _ = cell.read();
    let before = allocs();
    for epoch in 3..1_003u64 {
        let (e, snap) = cell.read();
        assert_eq!(e, epoch - 1);
        assert_eq!(snap.len(), dim);
        drop(snap);
        cell.publish(&theta, epoch);
    }
    let cell_allocs = allocs() - before;
    assert_eq!(
        cell_allocs, 0,
        "ThetaCell steady state hit the allocator {cell_allocs} times over \
         1000 read/publish cycles"
    );
}
