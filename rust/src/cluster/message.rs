//! Typed master <-> worker messages for the threaded ("real") runtime.

use std::sync::Arc;

/// Master -> worker.
#[derive(Clone, Debug)]
pub enum MasterMsg {
    /// Compute a gradient at `theta` for iteration `iter`.
    /// `theta` is shared (Arc) so a broadcast does not clone M times.
    Work { iter: u64, theta: Arc<Vec<f32>> },
    /// Orderly shutdown.
    Shutdown,
}

/// Worker -> master.
#[derive(Debug)]
pub enum WorkerMsg {
    /// A finished gradient.
    Grad {
        worker: usize,
        iter: u64,
        grad: Vec<f32>,
        /// Shard loss contribution (sum of squared residuals for KRR,
        /// summed NLL for the LM), if the executable provides it.
        loss_sum: Option<f64>,
        /// Examples that contributed (the paper's ζ).
        examples: usize,
        /// Pure compute time (excludes injected delay), seconds.
        compute_secs: f64,
    },
    /// Worker hit an unrecoverable error and is exiting.
    Fatal { worker: usize, error: String },
    /// Worker simulated a crash (fault injection) and stops responding.
    SimulatedCrash { worker: usize, iter: u64 },
}

impl WorkerMsg {
    pub fn worker(&self) -> usize {
        match self {
            WorkerMsg::Grad { worker, .. }
            | WorkerMsg::Fatal { worker, .. }
            | WorkerMsg::SimulatedCrash { worker, .. } => *worker,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn broadcast_shares_theta() {
        let theta = Arc::new(vec![1.0f32; 1024]);
        let msgs: Vec<MasterMsg> = (0..8)
            .map(|_| MasterMsg::Work {
                iter: 1,
                theta: Arc::clone(&theta),
            })
            .collect();
        assert_eq!(Arc::strong_count(&theta), 9);
        drop(msgs);
        assert_eq!(Arc::strong_count(&theta), 1);
    }

    #[test]
    fn worker_accessor() {
        let m = WorkerMsg::Fatal {
            worker: 3,
            error: "x".into(),
        };
        assert_eq!(m.worker(), 3);
    }
}
