//! Log-bucketed latency histogram (HdrHistogram-lite).

/// Histogram with logarithmically spaced buckets over `[min_val, max_val]`.
#[derive(Clone, Debug)]
pub struct Histogram {
    buckets: Vec<u64>,
    min_val: f64,
    ratio: f64, // log-width per bucket
    count: u64,
    sum: f64,
    overflow: u64,
    underflow: u64,
}

impl Histogram {
    /// `n_buckets` log-spaced buckets spanning `[min_val, max_val]`.
    pub fn new(min_val: f64, max_val: f64, n_buckets: usize) -> Histogram {
        assert!(min_val > 0.0 && max_val > min_val && n_buckets > 0);
        Histogram {
            buckets: vec![0; n_buckets],
            min_val,
            ratio: (max_val / min_val).ln() / n_buckets as f64,
            count: 0,
            sum: 0.0,
            overflow: 0,
            underflow: 0,
        }
    }

    /// Default latency histogram: 1µs .. 100s.
    pub fn latency() -> Histogram {
        Histogram::new(1e-6, 100.0, 180)
    }

    pub fn record(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        if v < self.min_val {
            self.underflow += 1;
            return;
        }
        let idx = ((v / self.min_val).ln() / self.ratio) as usize;
        if idx >= self.buckets.len() {
            self.overflow += 1;
        } else {
            self.buckets[idx] += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Approximate quantile from bucket boundaries (upper edge).
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        if self.count == 0 {
            return 0.0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut seen = self.underflow;
        if seen >= target {
            return self.min_val;
        }
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return self.min_val * ((i + 1) as f64 * self.ratio).exp();
            }
        }
        f64::INFINITY
    }

    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.buckets.len(), other.buckets.len());
        assert_eq!(self.min_val, other.min_val);
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.overflow += other.overflow;
        self.underflow += other.underflow;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn quantiles_bracket_distribution() {
        let mut h = Histogram::latency();
        let mut rng = Pcg64::seeded(1);
        for _ in 0..100_000 {
            h.record(rng.lognormal(-5.0, 1.0));
        }
        // true median = exp(-5) ≈ 6.74ms
        let p50 = h.quantile(0.5);
        assert!(
            (p50 / (-5.0f64).exp() - 1.0).abs() < 0.1,
            "p50={p50} want≈{}",
            (-5.0f64).exp()
        );
        assert!(h.quantile(0.99) > p50);
        assert!((h.mean() / (-5.0f64 + 0.5).exp() - 1.0).abs() < 0.05);
    }

    #[test]
    fn overflow_underflow() {
        let mut h = Histogram::new(1.0, 10.0, 10);
        h.record(0.5);
        h.record(50.0);
        h.record(5.0);
        assert_eq!(h.count(), 3);
        assert_eq!(h.quantile(0.01), 1.0); // underflow clamps to min
        assert!(h.quantile(1.0).is_infinite()); // overflow above range
    }

    #[test]
    fn empty_histogram_quantiles_and_mean() {
        let h = Histogram::new(1.0, 10.0, 10);
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.0), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.quantile(1.0), 0.0);
    }

    #[test]
    fn single_sample_quantiles_collapse_to_its_bucket() {
        let mut h = Histogram::new(1.0, 100.0, 20);
        h.record(7.0);
        assert_eq!(h.count(), 1);
        assert!((h.mean() - 7.0).abs() < 1e-12);
        // Every positive quantile of a one-sample histogram is that
        // sample's bucket upper edge (ceil(q·1)=1 targets the only sample).
        let edge = h.quantile(1.0);
        assert!(edge >= 7.0 && edge < 7.0 * (100.0f64 / 1.0).powf(1.0 / 20.0));
        assert_eq!(h.quantile(0.5), edge);
        assert_eq!(h.quantile(0.01), edge);
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        let samples: Vec<f64> = (1..=90).map(|i| i as f64).collect();
        let fill = |range: std::ops::Range<usize>| {
            let mut h = Histogram::new(1.0, 100.0, 30);
            for &v in &samples[range] {
                h.record(v);
            }
            h
        };
        // (a ∪ b) ∪ c
        let mut left = fill(0..30);
        left.merge(&fill(30..60));
        left.merge(&fill(60..90));
        // a ∪ (c ∪ b) — different association and order
        let mut right_inner = fill(60..90);
        right_inner.merge(&fill(30..60));
        let mut right = fill(0..30);
        right.merge(&right_inner);
        assert_eq!(left.count(), right.count());
        assert!((left.mean() - right.mean()).abs() < 1e-9);
        for q in [0.01, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
            assert_eq!(left.quantile(q), right.quantile(q), "q={q}");
        }
    }

    #[test]
    fn p50_p99_edges_on_known_mass() {
        // Bucket edges are exp(k·ln(max/min)/n) — compare with a relative
        // tolerance, not bitwise, since exp(ln(x)) need not round-trip.
        fn close(a: f64, b: f64) -> bool {
            (a / b - 1.0).abs() < 1e-12
        }
        // 99 samples all in one bucket: every quantile is that bucket edge.
        let mut h = Histogram::new(1.0, 100.0, 2); // buckets [1,10), [10,100)
        for _ in 0..99 {
            h.record(2.0);
        }
        assert!(close(h.quantile(0.5), 10.0));
        assert!(close(h.quantile(0.99), 10.0));
        // One sample in the upper bucket: p99 of 100 targets sample #99,
        // still in the lower bucket; p100 crosses into the upper edge.
        h.record(50.0);
        assert_eq!(h.count(), 100);
        assert!(close(h.quantile(0.5), 10.0));
        assert!(close(h.quantile(0.99), 10.0));
        assert!(close(h.quantile(1.0), 100.0));
    }

    #[test]
    fn merge_combines() {
        let mut a = Histogram::new(1.0, 100.0, 20);
        let mut b = Histogram::new(1.0, 100.0, 20);
        for i in 1..=50 {
            a.record(i as f64);
        }
        for i in 51..=100 {
            b.record(i as f64);
        }
        a.merge(&b);
        assert_eq!(a.count(), 100);
        let p50 = a.quantile(0.5);
        assert!(p50 > 40.0 && p50 < 65.0, "p50={p50}");
    }
}
