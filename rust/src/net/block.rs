//! Partial-gradient block admission: fixed-size blocks of a `Grad` reply
//! whose fates the network realizes independently.
//!
//! Yu et al. (arXiv:1810.07766) prove convergence when each iteration
//! delivers only a random *subset* of gradient blocks; this module gives
//! the transport layer the vocabulary for that model.  A reply's payload
//! (each per-shard gradient vector) is chunked into at most [`MAX_BLOCKS`]
//! equal ranges, and a [`BlockSet`] records which of them survived the
//! uplink.  With blocking disabled (`block_size = 0`, the default) every
//! reply is a single block and the whole layer reduces to the legacy
//! binary delivered/dropped decision, bit for bit.

/// Hard cap on blocks per reply — the delivered set packs into one `u64`
/// mask, which keeps [`BlockSet`] `Copy` and the steady state zero-alloc.
pub const MAX_BLOCKS: usize = 64;

/// Which blocks of one reply survived: a bitmask over `n` blocks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockSet {
    mask: u64,
    n: u8,
}

impl BlockSet {
    /// All `n` blocks delivered.  `n` is clamped to `1..=MAX_BLOCKS`.
    pub fn full(n: usize) -> BlockSet {
        let n = n.clamp(1, MAX_BLOCKS);
        let mask = if n == MAX_BLOCKS { u64::MAX } else { (1u64 << n) - 1 };
        BlockSet { mask, n: n as u8 }
    }

    /// No blocks delivered.
    pub fn empty(n: usize) -> BlockSet {
        BlockSet { mask: 0, n: n.clamp(1, MAX_BLOCKS) as u8 }
    }

    /// Number of blocks the reply was chunked into.
    pub fn len(&self) -> usize {
        self.n as usize
    }

    pub fn is_empty(&self) -> bool {
        self.mask == 0
    }

    pub fn is_full(&self) -> bool {
        self.delivered() == self.len()
    }

    /// Number of delivered blocks.
    pub fn delivered(&self) -> usize {
        self.mask.count_ones() as usize
    }

    /// Delivered fraction in `[0, 1]`; exactly `1.0` when full, so
    /// full-mask weights multiply out bit-identically to the pre-block
    /// aggregation.
    pub fn fraction(&self) -> f64 {
        self.delivered() as f64 / self.len() as f64
    }

    pub fn contains(&self, block: usize) -> bool {
        block < self.len() && self.mask & (1u64 << block) != 0
    }

    /// Self with `block` marked delivered.
    pub fn with(mut self, block: usize) -> BlockSet {
        debug_assert!(block < self.len());
        self.mask |= 1u64 << block;
        self
    }

    /// Blocks in `self` not already in `claimed` (same `n`).
    pub fn minus(&self, claimed: BlockSet) -> BlockSet {
        BlockSet { mask: self.mask & !claimed.mask, n: self.n }
    }

    /// Raw mask, for ledger bookkeeping.
    pub fn mask(&self) -> u64 {
        self.mask
    }

    /// Coordinate range `[start, end)` of `block` in a `dim`-length
    /// gradient: the even split `start = b·dim/n`, `end = (b+1)·dim/n`,
    /// which partitions every `dim` exactly (last blocks absorb the
    /// remainder).
    pub fn range(&self, block: usize, dim: usize) -> (usize, usize) {
        let n = self.len();
        (block * dim / n, (block + 1) * dim / n)
    }
}

/// Double-count guard for block admission: each `(worker, iter, block)`
/// folds into θ **at most once**, even when a duplicated reply straggles
/// into a later window with an overlapping block set.  The drivers claim
/// a reply's delivered set when they fold it; a later claim for the same
/// `(worker, iter)` returns only the still-unclaimed blocks.
#[derive(Debug, Default)]
pub struct BlockLedger {
    entries: Vec<(usize, u64, u64)>,
}

impl BlockLedger {
    pub fn new() -> BlockLedger {
        BlockLedger::default()
    }

    /// Claim `blocks` for `(worker, iter)`; returns the subset that was
    /// not already claimed (the blocks safe to fold).
    pub fn claim(&mut self, worker: usize, iter: u64, blocks: BlockSet) -> BlockSet {
        for e in self.entries.iter_mut() {
            if e.0 == worker && e.1 == iter {
                let fresh = BlockSet { mask: blocks.mask & !e.2, n: blocks.n };
                e.2 |= blocks.mask;
                return fresh;
            }
        }
        self.entries.push((worker, iter, blocks.mask));
        blocks
    }

    /// Drop entries older than `iter` — stragglers that far behind can no
    /// longer pop (the reorder window is bounded), so the scan stays
    /// short-lived.
    pub fn prune_before(&mut self, iter: u64) {
        self.entries.retain(|e| e.1 >= iter);
    }

    pub fn clear(&mut self) {
        self.entries.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_and_empty_extremes() {
        let f = BlockSet::full(8);
        assert_eq!(f.len(), 8);
        assert_eq!(f.delivered(), 8);
        assert!(f.is_full() && !f.is_empty());
        assert_eq!(f.fraction(), 1.0);
        let e = BlockSet::empty(8);
        assert_eq!(e.delivered(), 0);
        assert!(e.is_empty() && !e.is_full());
        assert_eq!(e.fraction(), 0.0);
        // The single-block degenerate case the legacy model maps onto.
        assert!(BlockSet::full(1).is_full());
        assert_eq!(BlockSet::full(1).fraction(), 1.0);
        // The mask cap.
        assert_eq!(BlockSet::full(MAX_BLOCKS).delivered(), MAX_BLOCKS);
        assert_eq!(BlockSet::full(MAX_BLOCKS + 7).len(), MAX_BLOCKS);
    }

    #[test]
    fn insert_contains_minus() {
        let s = BlockSet::empty(4).with(0).with(2);
        assert!(s.contains(0) && s.contains(2));
        assert!(!s.contains(1) && !s.contains(3));
        assert_eq!(s.delivered(), 2);
        assert_eq!(s.fraction(), 0.5);
        let t = BlockSet::empty(4).with(2).with(3);
        let fresh = t.minus(s);
        assert!(fresh.contains(3) && !fresh.contains(2));
        assert_eq!(fresh.delivered(), 1);
    }

    #[test]
    fn ranges_partition_the_dimension() {
        for &n in &[1usize, 2, 3, 5, 8] {
            for &dim in &[1usize, 7, 16, 33] {
                let s = BlockSet::full(n);
                let mut covered = 0usize;
                let mut prev_end = 0usize;
                for b in 0..n {
                    let (lo, hi) = s.range(b, dim);
                    assert_eq!(lo, prev_end, "gap before block {b} (n={n}, dim={dim})");
                    assert!(hi >= lo);
                    covered += hi - lo;
                    prev_end = hi;
                }
                assert_eq!(prev_end, dim);
                assert_eq!(covered, dim);
            }
        }
    }

    #[test]
    fn ledger_claims_each_block_once() {
        let mut ledger = BlockLedger::new();
        let first = BlockSet::empty(4).with(0).with(1);
        assert_eq!(ledger.claim(3, 7, first), first);
        // Overlapping duplicate: only the unclaimed block comes back.
        let dup = BlockSet::empty(4).with(1).with(2);
        let fresh = ledger.claim(3, 7, dup);
        assert!(fresh.contains(2) && !fresh.contains(1));
        // A full claim recovers only the remaining block; after that,
        // nothing is fresh.
        assert_eq!(ledger.claim(3, 7, BlockSet::full(4)), BlockSet::empty(4).with(3));
        assert!(ledger.claim(3, 7, BlockSet::full(4)).is_empty());
        // Other (worker, iter) keys are independent.
        assert_eq!(ledger.claim(2, 7, dup), dup);
        assert_eq!(ledger.claim(3, 8, dup), dup);
    }

    #[test]
    fn ledger_prunes_old_iterations() {
        let mut ledger = BlockLedger::new();
        ledger.claim(0, 1, BlockSet::full(2));
        ledger.claim(0, 5, BlockSet::full(2));
        ledger.prune_before(4);
        // The iter-1 entry is gone: a re-claim gets everything back.
        assert_eq!(ledger.claim(0, 1, BlockSet::full(2)), BlockSet::full(2));
        // The iter-5 entry survived.
        assert!(ledger.claim(0, 5, BlockSet::full(2)).is_empty());
    }
}
