//! JSON parser (for `artifacts/manifest.json`) — recursive descent, no deps.

use std::collections::BTreeMap;

use super::value::Value;
use crate::{Error, Result};

/// Parse a JSON document into a [`Value`].
pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after JSON value"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        let (mut line, mut col) = (1usize, 1usize);
        for &b in &self.bytes[..self.pos.min(self.bytes.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        Error::Config(format!("json parse error at {line}:{col}: {msg}"))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected '{lit}'")))
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Table(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            map.insert(key, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Table(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex in \\u escape"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape sequence")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated UTF-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("invalid UTF-8"))?;
                        out.push_str(s);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut is_float = false;
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    is_float = true;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if is_float {
            text.parse::<f64>()
                .map(Value::Float)
                .map_err(|_| self.err(&format!("bad float '{text}'")))
        } else {
            text.parse::<i64>()
                .map(Value::Int)
                .map_err(|_| self.err(&format!("bad integer '{text}'")))
        }
    }
}

/// Serialize a [`Value`] back to compact JSON (used by metric writers).
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Int(i) => out.push_str(&i.to_string()),
        Value::Float(f) => {
            if f.is_finite() {
                out.push_str(&format!("{f}"));
                if f.fract() == 0.0 && !out.ends_with(|c: char| c == '.' || c == 'e') {
                    // keep floats distinguishable from ints on re-parse
                    if !format!("{f}").contains(['.', 'e', 'E']) {
                        out.push_str(".0");
                    }
                }
            } else {
                out.push_str("null");
            }
        }
        Value::Str(s) => write_string(s, out),
        Value::Array(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Value::Table(map) => {
            out.push('{');
            for (i, (k, item)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(item, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap(), Value::Int(42));
        assert_eq!(parse("-3.5").unwrap(), Value::Float(-3.5));
        assert_eq!(parse("1e3").unwrap(), Value::Float(1000.0));
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": false}}"#).unwrap();
        assert_eq!(v.get("d.e"), Some(&Value::Bool(false)));
        let arr = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn parses_unicode_passthrough() {
        let v = parse("\"héllo → ∞\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → ∞"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn error_has_position() {
        let e = parse("{\n  \"a\": ]\n}").unwrap_err();
        let msg = format!("{e}");
        assert!(msg.contains("2:"), "{msg}");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"n":null,"t":true}"#;
        let v = parse(src).unwrap();
        let back = to_string(&v);
        assert_eq!(parse(&back).unwrap(), v);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let doc = r#"{
          "format_version": 1,
          "artifacts": {
            "krr_worker_grad_default": {
              "file": "krr_worker_grad_default.hlo.txt",
              "inputs": [{"name": "theta", "shape": [64], "dtype": "f32"}],
              "outputs": [{"shape": [64], "dtype": "f32"}],
              "meta": {"l": 64, "zeta": 2048}
            }
          }
        }"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.req_usize("format_version").unwrap(), 1);
        let e = v.get("artifacts.krr_worker_grad_default").unwrap();
        assert_eq!(e.req_str("file").unwrap(), "krr_worker_grad_default.hlo.txt");
        assert_eq!(e.get("inputs").unwrap().as_array().unwrap().len(), 1);
    }
}
