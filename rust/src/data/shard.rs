//! Row-sharding of a feature matrix across M workers, plus the shard
//! **ownership map** the elastic cluster subsystem rebalances.
//!
//! The seed system hard-wired shard `s` to worker `s` forever, so a crashed
//! worker's rows silently stopped contributing and biased the aggregate.
//! [`OwnershipMap`] decouples *data partition* (fixed `Shard`s) from
//! *assignment* (which worker computes which shard this iteration), and
//! [`plan_rebalance`] produces a deterministic [`RebalancePlan`] that moves
//! orphaned shards onto live workers and levels load — the same plan is
//! executed by both drivers ([`crate::sim`] and [`crate::worker`]), so
//! semantics stay shared.
//!
//! **Capacity-aware clusters** (see `docs/ELASTIC.md`): on mixed hardware a
//! level shard count is *not* a level load, so
//! [`plan_rebalance_weighted`] apportions shards proportionally to each
//! worker's relative capacity weight by deterministic **largest-remainder**
//! apportionment.  Uniform weights delegate to the legacy planner, so every
//! pre-capacity plan — and hence every golden trajectory — is reproduced
//! bit for bit.
//!
//! Invariants (property-tested in `tests/property_shard.rs`):
//! * every shard has exactly one owner (no row lost, no row owned twice);
//! * after a rebalance every owner is alive (when anyone is);
//! * alive loads differ by at most one shard (uniform weights) / by less
//!   than one from their fractional quota (weighted);
//! * with unchanged, already-even membership the plan is empty
//!   (`split_even` round-trips through rebalance to the identity);
//! * with no worker alive the plan moves nothing and surfaces the
//!   unadoptable shards in [`RebalancePlan::orphans`].

/// One worker's slice of the dataset: `phi` is row-major (rows, l).
#[derive(Clone, Debug)]
pub struct Shard {
    pub phi: Vec<f32>,
    pub y: Vec<f32>,
    pub rows: usize,
    pub l: usize,
}

impl Shard {
    pub fn new(phi: Vec<f32>, y: Vec<f32>, rows: usize, l: usize) -> Shard {
        assert_eq!(phi.len(), rows * l);
        assert_eq!(y.len(), rows);
        Shard { phi, y, rows, l }
    }
}

/// Split `(phi, y)` into `m` equal shards of `zeta` rows each.
/// Panics unless `rows == m * zeta` (the AOT artifacts are fixed-shape, so
/// the generator always produces exactly `m * zeta` rows).
pub fn split_even(phi: &[f32], y: &[f32], l: usize, m: usize, zeta: usize) -> Vec<Shard> {
    let rows = y.len();
    assert_eq!(phi.len(), rows * l);
    assert_eq!(rows, m * zeta, "rows {rows} != m {m} * zeta {zeta}");
    (0..m)
        .map(|w| {
            let lo = w * zeta;
            let hi = lo + zeta;
            Shard::new(
                phi[lo * l..hi * l].to_vec(),
                y[lo..hi].to_vec(),
                zeta,
                l,
            )
        })
        .collect()
}

// ---------------------------------------------------------------------
// Ownership map + rebalance plans (elastic cluster subsystem)
// ---------------------------------------------------------------------

/// Which worker owns (computes) each shard.  `owner[shard] = worker`, so by
/// construction every shard has exactly one owner.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct OwnershipMap {
    owner: Vec<usize>,
    workers: usize,
}

impl OwnershipMap {
    /// Even assignment: shard `s` to worker `s % workers`.  With
    /// `shards == workers` (the seed layout) this is the identity.
    pub fn even(shards: usize, workers: usize) -> OwnershipMap {
        assert!(workers > 0, "ownership needs at least one worker");
        OwnershipMap {
            owner: (0..shards).map(|s| s % workers).collect(),
            workers,
        }
    }

    /// The seed layout: one shard per worker, shard `s` owned by worker `s`.
    pub fn identity(m: usize) -> OwnershipMap {
        OwnershipMap::even(m, m)
    }

    pub fn shards(&self) -> usize {
        self.owner.len()
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn owner(&self, shard: usize) -> usize {
        self.owner[shard]
    }

    /// The full owner-per-shard vector (index = shard).  Reports snapshot
    /// this at run end so tests can assert cross-driver ownership parity.
    pub fn owners(&self) -> &[usize] {
        &self.owner
    }

    /// Number of shards worker `w` currently owns.
    pub fn load(&self, w: usize) -> usize {
        self.owner.iter().filter(|&&o| o == w).count()
    }

    /// All loads, indexed by worker.
    pub fn loads(&self) -> Vec<usize> {
        let mut loads = vec![0usize; self.workers];
        for &o in &self.owner {
            loads[o] += 1;
        }
        loads
    }

    /// Shards worker `w` owns, in ascending shard order (the deterministic
    /// compute/aggregation order both drivers use).
    pub fn shards_of(&self, w: usize) -> Vec<usize> {
        (0..self.owner.len()).filter(|&s| self.owner[s] == w).collect()
    }

    /// All per-worker shard lists (each ascending), computed in one
    /// O(shards) pass — the per-iteration form of [`Self::shards_of`] for
    /// the drivers' hot loops.
    pub fn grouped(&self) -> Vec<Vec<usize>> {
        let mut by_worker = Vec::new();
        self.grouped_into(&mut by_worker);
        by_worker
    }

    /// [`Self::grouped`] into a caller-owned buffer: the outer and inner
    /// `Vec`s keep their capacity across calls, so the virtual driver's
    /// per-iteration assignment snapshot allocates nothing in steady state.
    pub fn grouped_into(&self, out: &mut Vec<Vec<usize>>) {
        out.resize_with(self.workers, Vec::new);
        for v in out.iter_mut() {
            v.clear();
        }
        for (s, &o) in self.owner.iter().enumerate() {
            out[o].push(s);
        }
    }

    /// Point reassignment (BSP-retry's Hadoop-style permanent takeover).
    pub fn reassign(&mut self, shard: usize, new_owner: usize) {
        assert!(new_owner < self.workers, "owner {new_owner} out of range");
        self.owner[shard] = new_owner;
    }

    /// Execute a rebalance plan.  Errors if a move's `from` no longer holds
    /// the shard (a stale plan), leaving the map unchanged in that case.
    pub fn apply(&mut self, plan: &RebalancePlan) -> Result<(), String> {
        for mv in &plan.moves {
            if self.owner.get(mv.shard) != Some(&mv.from) {
                return Err(format!(
                    "stale rebalance move: shard {} owned by {}, plan says {}",
                    mv.shard,
                    self.owner.get(mv.shard).copied().unwrap_or(usize::MAX),
                    mv.from
                ));
            }
        }
        for mv in &plan.moves {
            self.owner[mv.shard] = mv.to;
        }
        Ok(())
    }
}

/// One shard migration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardMove {
    pub shard: usize,
    pub from: usize,
    pub to: usize,
}

/// A batch of shard migrations computed at an iteration boundary.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RebalancePlan {
    pub moves: Vec<ShardMove>,
    /// Shards that remain owned by a dead worker because there was nowhere
    /// to move them — non-empty only when *no* worker is alive.  Surfaced
    /// so callers can assert conservation: every dead-owned shard is either
    /// moved or reported here, never silently forgotten.
    pub orphans: Vec<usize>,
}

impl RebalancePlan {
    /// No moves to apply.  A plan can be "empty" and still carry
    /// [`RebalancePlan::orphans`] (the everyone-dead case).
    pub fn is_empty(&self) -> bool {
        self.moves.is_empty()
    }

    pub fn len(&self) -> usize {
        self.moves.len()
    }
}

/// Compute a deterministic rebalance plan over the live worker set:
///
/// 1. every shard owned by a dead worker moves to the least-loaded alive
///    worker (ties break toward the lowest worker index);
/// 2. loads are then levelled: while some alive worker owns ≥ 2 more
///    shards than another, its highest-index shard moves to the
///    least-loaded alive worker.
///
/// With every worker alive and loads already level the plan is empty, so
/// rebalancing is the identity on an unchanged balanced cluster.  If no
/// worker is alive the plan moves nothing and the unadoptable shards are
/// surfaced in [`RebalancePlan::orphans`].
pub fn plan_rebalance(map: &OwnershipMap, alive: &[bool]) -> RebalancePlan {
    assert_eq!(alive.len(), map.workers(), "alive mask size mismatch");
    let alive_workers: Vec<usize> = (0..alive.len()).filter(|&w| alive[w]).collect();
    let mut plan = RebalancePlan::default();
    if alive_workers.is_empty() {
        plan.orphans = (0..map.shards()).filter(|&s| !alive[map.owner(s)]).collect();
        return plan;
    }

    let mut loads = map.loads();
    // Track pending ownership so levelling sees pass-1 moves.
    let mut owner: Vec<usize> = (0..map.shards()).map(|s| map.owner(s)).collect();

    let least_loaded = |loads: &[usize]| -> usize {
        let mut best = alive_workers[0];
        for &w in &alive_workers {
            if loads[w] < loads[best] {
                best = w;
            }
        }
        best
    };

    // Pass 1: adopt orphaned shards.
    for s in 0..owner.len() {
        let o = owner[s];
        if !alive[o] {
            let to = least_loaded(&loads);
            plan.moves.push(ShardMove { shard: s, from: o, to });
            loads[o] -= 1;
            loads[to] += 1;
            owner[s] = to;
        }
    }

    // Pass 2: level loads among alive workers to within one shard.
    loop {
        let mut donor = alive_workers[0];
        for &w in &alive_workers {
            if loads[w] > loads[donor] {
                donor = w;
            }
        }
        let recipient = least_loaded(&loads);
        if loads[donor] <= loads[recipient] + 1 {
            break;
        }
        // Donor's highest-index shard migrates (low shards stay sticky,
        // minimizing churn for workers that keep their original data).
        let shard = (0..owner.len())
            .rev()
            .find(|&s| owner[s] == donor)
            .expect("donor with positive load owns a shard");
        plan.moves.push(ShardMove { shard, from: donor, to: recipient });
        loads[donor] -= 1;
        loads[recipient] += 1;
        owner[shard] = recipient;
    }

    plan
}

/// Capacity-weighted rebalance: apportion the shard count over the live
/// worker set proportionally to `weights` (relative capacities, > 0) by
/// deterministic **largest-remainder** apportionment, then emit the moves
/// that realize the apportionment with minimal churn:
///
/// 1. each alive worker's target is `floor(S · wᵢ / ΣW)`, and the leftover
///    shards go to the largest fractional remainders — ties prefer workers
///    already holding more than their floor (stickiness: a replan with
///    unchanged weights and loads is the empty plan), then the lowest
///    worker index;
/// 2. dead workers' shards move first (ascending shard index) to the most
///    under-target alive worker (ties toward the lowest index);
/// 3. over-target workers then donate their highest-index shards to the
///    most under-target workers until every alive load equals its target.
///
/// **Uniform weights delegate to [`plan_rebalance`]**, so a homogeneous
/// cluster's plans — move lists included — are bit-for-bit the legacy
/// planner's, which is what keeps every pre-capacity golden trajectory
/// unchanged.  With no worker alive the plan moves nothing and surfaces
/// the unadoptable shards in [`RebalancePlan::orphans`].
pub fn plan_rebalance_weighted(
    map: &OwnershipMap,
    alive: &[bool],
    weights: &[f64],
) -> RebalancePlan {
    assert_eq!(alive.len(), map.workers(), "alive mask size mismatch");
    assert_eq!(weights.len(), map.workers(), "weight vector size mismatch");
    let alive_workers: Vec<usize> = (0..alive.len()).filter(|&w| alive[w]).collect();
    if alive_workers.is_empty() {
        return plan_rebalance(map, alive);
    }
    for &w in &alive_workers {
        assert!(
            weights[w] > 0.0 && weights[w].is_finite(),
            "weight of alive worker {w} must be positive and finite, got {}",
            weights[w]
        );
    }
    // Uniform weights: capacity carries no information, so the legacy
    // planner *is* the apportionment — and its exact move lists are pinned
    // by the golden/parity suites.
    let w0 = weights[alive_workers[0]];
    if alive_workers.iter().all(|&w| weights[w] == w0) {
        return plan_rebalance(map, alive);
    }

    let shards = map.shards();
    let loads = map.loads();
    let total: f64 = alive_workers.iter().map(|&w| weights[w]).sum();

    // Largest-remainder apportionment of `shards` over the alive set.
    let mut target = vec![0usize; map.workers()];
    let mut fracs: Vec<(f64, usize)> = Vec::with_capacity(alive_workers.len());
    let mut assigned = 0usize;
    for &w in &alive_workers {
        let quota = shards as f64 * weights[w] / total;
        let base = quota.floor() as usize;
        target[w] = base;
        assigned += base;
        fracs.push((quota - base as f64, w));
    }
    let extras = shards.saturating_sub(assigned);
    fracs.sort_by(|a, b| {
        b.0.partial_cmp(&a.0)
            .expect("quota fractions are finite")
            // Stickiness: a worker already holding more than its floor
            // keeps its extra, so replanning unchanged state is a no-op.
            .then_with(|| (loads[b.1] > target[b.1]).cmp(&(loads[a.1] > target[a.1])))
            .then_with(|| a.1.cmp(&b.1))
    });
    // `extras` ≤ alive count in exact arithmetic; `cycle` keeps the
    // distribution total (Σ target == shards, which pass 2's termination
    // depends on) even if f64 rounding ever floors one quota too low.
    for &(_, w) in fracs.iter().cycle().take(extras) {
        target[w] += 1;
    }

    // Emit moves: track pending ownership so later picks see earlier ones.
    let mut plan = RebalancePlan::default();
    let mut owner: Vec<usize> = (0..shards).map(|s| map.owner(s)).collect();
    let mut load = loads;
    let most_under = |load: &[usize]| -> usize {
        let mut best = alive_workers[0];
        let mut best_deficit = target[best] as i64 - load[best] as i64;
        for &w in &alive_workers {
            let deficit = target[w] as i64 - load[w] as i64;
            if deficit > best_deficit {
                best = w;
                best_deficit = deficit;
            }
        }
        best
    };

    // Pass 1: adopt dead workers' shards.
    for s in 0..shards {
        let o = owner[s];
        if !alive[o] {
            let to = most_under(&load);
            plan.moves.push(ShardMove { shard: s, from: o, to });
            load[o] -= 1;
            load[to] += 1;
            owner[s] = to;
        }
    }

    // Pass 2: drain over-target workers into under-target ones.  Total
    // excess equals total deficit (both sides sum to `shards`), so this
    // terminates with every alive load exactly on target.
    loop {
        let mut donor = None;
        let mut worst = 0i64;
        for &w in &alive_workers {
            let excess = load[w] as i64 - target[w] as i64;
            if excess > worst {
                donor = Some(w);
                worst = excess;
            }
        }
        let Some(donor) = donor else { break };
        let to = most_under(&load);
        // Donor's highest-index shard migrates (low shards stay sticky).
        let shard = (0..owner.len())
            .rev()
            .find(|&s| owner[s] == donor)
            .expect("over-target donor owns a shard");
        plan.moves.push(ShardMove { shard, from: donor, to });
        load[donor] -= 1;
        load[to] += 1;
        owner[shard] = to;
    }

    plan
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_evenly_preserving_rows() {
        let l = 2;
        let rows = 6;
        let phi: Vec<f32> = (0..rows * l).map(|i| i as f32).collect();
        let y: Vec<f32> = (0..rows).map(|i| i as f32 * 10.0).collect();
        let shards = split_even(&phi, &y, l, 3, 2);
        assert_eq!(shards.len(), 3);
        assert_eq!(shards[1].phi, vec![4.0, 5.0, 6.0, 7.0]);
        assert_eq!(shards[1].y, vec![20.0, 30.0]);
        assert_eq!(shards[2].rows, 2);
    }

    #[test]
    #[should_panic]
    fn rejects_uneven() {
        split_even(&[0.0; 10], &[0.0; 5], 2, 2, 2);
    }

    #[test]
    fn identity_map_owns_one_each() {
        let map = OwnershipMap::identity(4);
        assert_eq!(map.shards(), 4);
        for w in 0..4 {
            assert_eq!(map.owner(w), w);
            assert_eq!(map.load(w), 1);
            assert_eq!(map.shards_of(w), vec![w]);
        }
        assert_eq!(map.loads(), vec![1; 4]);
    }

    #[test]
    fn rebalance_is_identity_on_healthy_even_cluster() {
        let map = OwnershipMap::identity(6);
        let plan = plan_rebalance(&map, &[true; 6]);
        assert!(plan.is_empty(), "{plan:?}");
    }

    #[test]
    fn dead_workers_shards_adopted_by_least_loaded() {
        let mut map = OwnershipMap::identity(4);
        let alive = [true, false, true, true];
        let plan = plan_rebalance(&map, &alive);
        assert_eq!(plan.len(), 1);
        assert_eq!(plan.moves[0].shard, 1);
        assert_eq!(plan.moves[0].from, 1);
        // Lowest-index least-loaded alive worker adopts.
        assert_eq!(plan.moves[0].to, 0);
        map.apply(&plan).unwrap();
        assert_eq!(map.owner(1), 0);
        assert_eq!(map.load(0), 2);
    }

    #[test]
    fn rejoin_levels_load_back() {
        // Worker 1 died, its shard moved to 0; when 1 rejoins, levelling
        // hands a shard back.
        let mut map = OwnershipMap::identity(4);
        map.apply(&plan_rebalance(&map, &[true, false, true, true])).unwrap();
        assert_eq!(map.load(0), 2);
        assert_eq!(map.load(1), 0);
        let plan = plan_rebalance(&map, &[true; 4]);
        assert_eq!(plan.len(), 1);
        assert_eq!(plan.moves[0].from, 0);
        assert_eq!(plan.moves[0].to, 1);
        map.apply(&plan).unwrap();
        assert_eq!(map.loads(), vec![1; 4]);
    }

    #[test]
    fn stale_plan_rejected_without_mutation() {
        let mut map = OwnershipMap::identity(3);
        let plan = RebalancePlan {
            moves: vec![ShardMove { shard: 0, from: 2, to: 1 }],
            ..RebalancePlan::default()
        };
        assert!(map.apply(&plan).is_err());
        assert_eq!(map, OwnershipMap::identity(3));
    }

    #[test]
    fn everyone_dead_yields_empty_plan_with_orphans() {
        let map = OwnershipMap::identity(3);
        let plan = plan_rebalance(&map, &[false; 3]);
        assert!(plan.is_empty());
        // The unadoptable shards are surfaced, not silently forgotten.
        assert_eq!(plan.orphans, vec![0, 1, 2]);
        // With anyone alive, everything is adopted and nothing is orphaned.
        let plan = plan_rebalance(&map, &[false, true, false]);
        assert!(plan.orphans.is_empty());
        assert_eq!(plan.len(), 2);
    }

    #[test]
    fn weighted_uniform_delegates_to_legacy() {
        // Any uniform weight vector must reproduce the legacy plan exactly
        // (move lists included), keeping homogeneous goldens bit-for-bit.
        let map = OwnershipMap::identity(5);
        let alive = [true, false, true, true, false];
        let legacy = plan_rebalance(&map, &alive);
        let weighted = plan_rebalance_weighted(&map, &alive, &[2.5; 5]);
        assert_eq!(legacy, weighted);
    }

    #[test]
    fn weighted_apportionment_strips_slow_half() {
        // 4 shards over 2 fast (1.0) + 2 slow (0.25) workers: quotas are
        // 1.6 / 0.4, so largest remainder gives the fast pair 2 shards each
        // and the slow pair none.
        let map = OwnershipMap::identity(4);
        let weights = [1.0, 1.0, 0.25, 0.25];
        let mut map2 = map.clone();
        let plan = plan_rebalance_weighted(&map, &[true; 4], &weights);
        map2.apply(&plan).unwrap();
        assert_eq!(map2.loads(), vec![2, 2, 0, 0], "{plan:?}");
        // Replanning the result with unchanged weights is a no-op.
        assert!(plan_rebalance_weighted(&map2, &[true; 4], &weights).is_empty());
    }

    #[test]
    fn weighted_proportionality_keeps_minority_slow_node() {
        // One 0.25× worker among three fast ones: its quota 4·0.25/3.25 ≈
        // 0.31 out-remainders the fast 0.23, so proportional apportionment
        // leaves it exactly its one shard — the identity plan.
        let map = OwnershipMap::identity(4);
        let plan = plan_rebalance_weighted(&map, &[true; 4], &[1.0, 1.0, 1.0, 0.25]);
        assert!(plan.is_empty(), "{plan:?}");
    }

    #[test]
    fn weighted_adopts_orphans_by_deficit() {
        // Worker 1 (weight 2.0) dies; its shard must go to the most
        // under-target survivor.  Targets over {0, 2, 3} with weights
        // {2, 1, 1}: quotas 2 / 1 / 1 — worker 0 is two under, adopts both.
        let mut map = OwnershipMap::identity(4);
        map.reassign(0, 1); // worker 1 owns shards 0 and 1, worker 0 none
        let alive = [true, false, true, true];
        let weights = [2.0, 2.0, 1.0, 1.0];
        let plan = plan_rebalance_weighted(&map, &alive, &weights);
        map.apply(&plan).unwrap();
        assert_eq!(map.loads(), vec![2, 0, 1, 1], "{plan:?}");
        assert!(plan.orphans.is_empty());
    }

    #[test]
    fn single_survivor_adopts_everything() {
        let mut map = OwnershipMap::identity(5);
        let alive = [false, false, true, false, false];
        let plan = plan_rebalance(&map, &alive);
        map.apply(&plan).unwrap();
        assert_eq!(map.load(2), 5);
        for s in 0..5 {
            assert_eq!(map.owner(s), 2);
        }
    }
}
