"""L1 Pallas kernel: kernel-ridge-regression gradient (the paper's Alg. 3 body).

Computes, for one slave's shard of ``zeta`` examples with feature matrix
``phi`` (zeta x l), labels ``y`` (zeta,) and parameters ``theta`` (l,):

    g = (1/zeta) * phi^T (phi @ theta - y) + lam * theta

This is the compute hot-spot of the whole system: every slave runs it once
per iteration.  The kernel is written for the TPU memory hierarchy (see
DESIGN.md §Hardware-Adaptation):

* the example dimension is tiled with ``BLOCK_M`` rows per grid step, so the
  ``phi`` tile streams HBM->VMEM block by block while ``theta`` and the
  gradient accumulator stay resident in VMEM;
* both the residual (``phi_tile @ theta``) and the back-projection
  (``phi_tile^T @ r``) are MXU-shaped matmuls (the feature dim ``l`` is kept
  whole inside a block; it is <= a few hundred in all our configs);
* the ``lam * theta`` term and the ``1/zeta`` scaling are fused into the
  first/last grid step so no separate elementwise pass is needed.

``interpret=True`` everywhere: real-TPU lowering emits a Mosaic custom-call
the CPU PJRT plugin cannot execute.  Correctness is pinned against
``ref.krr_grad`` by ``python/tests/test_krr_grad.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget for the streamed phi tile.  The tile is the only O(zeta)
# resident; theta/accumulator are O(l).  2 MiB leaves ample headroom in a
# 16 MiB VMEM for double-buffering the incoming tile (perf pass §Perf L1:
# bigger tiles also amortize grid-step overhead — on the CPU-interpret
# testbed the old 256-row default ran at 0.45x the pure-jnp roofline,
# VMEM-budget tiles run at 0.9-1.0x).
VMEM_TILE_BUDGET = 2 * 1024 * 1024
# Hard cap so huge shards still stream through a real multi-step grid.
MAX_BLOCK_M = 2048
# Back-compat default used by tests that pin a block size explicitly.
DEFAULT_BLOCK_M = 0  # 0 = auto (VMEM-derived)


def _auto_block(zeta: int, l: int) -> int:
    """Largest tile that fits the VMEM budget, divides zeta, caps at 2048."""
    bm = min(zeta, max(8, VMEM_TILE_BUDGET // (l * 4)), MAX_BLOCK_M)
    while zeta % bm != 0:
        bm -= 1
    return bm


def _krr_grad_kernel(theta_ref, phi_ref, y_ref, lam_ref, o_ref, *, zeta: int):
    """One grid step: accumulate phi_tile^T (phi_tile @ theta - y_tile).

    Grid steps run sequentially over the example tiles; step 0 seeds the
    accumulator with the regularization term so the final output needs no
    extra pass.
    """
    step = pl.program_id(0)
    theta = theta_ref[...]  # (l, 1), resident every step
    phi = phi_ref[...]  # (BLOCK_M, l) tile of this step
    y = y_ref[...]  # (BLOCK_M, 1) tile of this step

    # Residual on the tile: MXU matmul (BLOCK_M, l) @ (l, 1).
    r = jnp.dot(phi, theta, preferred_element_type=jnp.float32) - y
    # Back-projection: (l, BLOCK_M) @ (BLOCK_M, 1) -> (l, 1) partial grad.
    partial = jnp.dot(phi.T, r, preferred_element_type=jnp.float32)

    @pl.when(step == 0)
    def _seed():
        # Fuse the regularization term into the first accumulation step.
        o_ref[...] = lam_ref[...] * zeta * theta + partial

    @pl.when(step != 0)
    def _accum():
        o_ref[...] += partial


def krr_grad(theta, phi, y, lam, *, block_m: int = DEFAULT_BLOCK_M):
    """Pallas KRR gradient: ``(1/zeta) phi^T (phi theta - y) + lam theta``.

    Args:
      theta: (l,) float32 parameters.
      phi:   (zeta, l) float32 feature matrix (one slave's shard).
      y:     (zeta,) float32 labels.
      lam:   scalar float32 regularization strength.
      block_m: rows per grid step; must divide zeta.

    Returns:
      (l,) float32 gradient.
    """
    zeta, l = phi.shape
    if block_m <= 0:
        block_m = _auto_block(zeta, l)
    if zeta % block_m != 0:
        # Shrink to the largest divisor <= block_m so odd shard sizes work.
        bm = block_m
        while zeta % bm != 0:
            bm -= 1
        block_m = bm
    grid = (zeta // block_m,)

    theta2 = theta.reshape(l, 1)
    y2 = y.reshape(zeta, 1)
    lam2 = jnp.asarray(lam, jnp.float32).reshape(1, 1)

    kernel = functools.partial(_krr_grad_kernel, zeta=zeta)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # theta: whole vector resident in VMEM every step.
            pl.BlockSpec((l, 1), lambda i: (0, 0)),
            # phi: stream one (block_m, l) tile per step.
            pl.BlockSpec((block_m, l), lambda i: (i, 0)),
            # y: matching (block_m, 1) tile.
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
            # lam: scalar, resident.
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((l, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((l, 1), jnp.float32),
        interpret=True,
    )(theta2, phi, y2, lam2)
    return out.reshape(l) / zeta


def _krr_grad_loss_kernel(theta_ref, phi_ref, y_ref, lam_ref, o_ref, ss_ref, *, zeta: int):
    """Fused grid step: gradient accumulation + sum-of-squares in ONE sweep.

    The residual `r` is needed by both the gradient back-projection and the
    loss term; fusing them halves HBM traffic for the coordinator's hot
    `worker_grad_loss` artifact (perf pass, EXPERIMENTS.md §Perf L1)."""
    step = pl.program_id(0)
    theta = theta_ref[...]
    phi = phi_ref[...]
    y = y_ref[...]

    r = jnp.dot(phi, theta, preferred_element_type=jnp.float32) - y
    partial = jnp.dot(phi.T, r, preferred_element_type=jnp.float32)
    ss = jnp.sum(r * r).reshape(1, 1)

    @pl.when(step == 0)
    def _seed():
        o_ref[...] = lam_ref[...] * zeta * theta + partial
        ss_ref[...] = ss

    @pl.when(step != 0)
    def _accum():
        o_ref[...] += partial
        ss_ref[...] += ss


def krr_grad_loss(theta, phi, y, lam, *, block_m: int = DEFAULT_BLOCK_M):
    """Fused pallas KRR gradient + shard sum-of-squared-residuals.

    Single pass over ``phi``; returns ``(grad (l,), sumsq ())``.
    """
    zeta, l = phi.shape
    if block_m <= 0:
        block_m = _auto_block(zeta, l)
    if zeta % block_m != 0:
        bm = block_m
        while zeta % bm != 0:
            bm -= 1
        block_m = bm
    grid = (zeta // block_m,)

    kernel = functools.partial(_krr_grad_loss_kernel, zeta=zeta)
    grad, ss = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((l, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_m, l), lambda i: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((l, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((l, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=True,
    )(theta.reshape(l, 1), phi, y.reshape(zeta, 1), jnp.asarray(lam, jnp.float32).reshape(1, 1))
    return grad.reshape(l) / zeta, ss.reshape(())


def krr_loss_terms(theta, phi, y, *, block_m: int = DEFAULT_BLOCK_M):
    """Pallas sum-of-squared-residuals for the shard: ``sum (phi theta - y)^2``.

    Shares the tiling scheme of :func:`krr_grad`; used by the loss-eval
    artifact so the whole loss path also exercises the L1 layer.
    """
    zeta, l = phi.shape
    if block_m <= 0:
        block_m = _auto_block(zeta, l)
    if zeta % block_m != 0:
        bm = block_m
        while zeta % bm != 0:
            bm -= 1
        block_m = bm
    grid = (zeta // block_m,)

    def kernel(theta_ref, phi_ref, y_ref, o_ref):
        step = pl.program_id(0)
        r = (
            jnp.dot(phi_ref[...], theta_ref[...], preferred_element_type=jnp.float32)
            - y_ref[...]
        )
        ss = jnp.sum(r * r).reshape(1, 1)

        @pl.when(step == 0)
        def _seed():
            o_ref[...] = ss

        @pl.when(step != 0)
        def _accum():
            o_ref[...] += ss

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((l, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_m, l), lambda i: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=True,
    )(theta.reshape(l, 1), phi, y.reshape(zeta, 1))
    return out.reshape(())
