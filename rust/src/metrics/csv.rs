//! CSV export for loss curves and experiment tables (no csv crate offline).

use std::io::Write;
use std::path::Path;

use crate::metrics::recorder::Recorder;
use crate::Result;

/// Write a recorder's rows as CSV (one line per iteration).
pub fn write_recorder(rec: &Recorder, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    // New columns are appended last (`blocks`, `stale_blocks`, then
    // `recoveries`, `rollback_iters`, in that order) so existing
    // column-indexed readers keep working on older CSVs.
    writeln!(
        f,
        "iter,time,loss,eval_loss,theta_err,included,abandoned,stale,dropped,duplicated,alive,gamma,grad_norm,blocks,stale_blocks,recoveries,rollback_iters"
    )?;
    for r in rec.rows() {
        writeln!(
            f,
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}",
            r.iter,
            r.time,
            r.loss,
            opt(r.eval_loss),
            opt(r.theta_err),
            r.included,
            r.abandoned,
            r.stale,
            r.dropped,
            r.duplicated,
            r.alive,
            r.gamma.map(|g| g.to_string()).unwrap_or_default(),
            r.grad_norm,
            r.blocks,
            r.stale_blocks,
            r.recoveries,
            r.rollback_iters
        )?;
    }
    Ok(())
}

/// Write a generic table: header + stringified rows.
pub fn write_table(header: &[&str], rows: &[Vec<String>], path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        let cells: Vec<String> = row.iter().map(|s| escape(s)).collect();
        writeln!(f, "{}", cells.join(","))?;
    }
    Ok(())
}

fn opt(v: Option<f64>) -> String {
    v.map(|x| x.to_string()).unwrap_or_default()
}

fn escape(s: &str) -> String {
    if s.contains([',', '"', '\n']) {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::recorder::IterRow;

    #[test]
    fn writes_and_reads_back() {
        let mut rec = Recorder::new();
        rec.push(IterRow {
            iter: 0,
            time: 0.5,
            loss: 2.0,
            eval_loss: Some(2.1),
            theta_err: None,
            included: 3,
            abandoned: 1,
            stale: 2,
            dropped: 5,
            duplicated: 1,
            blocks: 6,
            stale_blocks: 2,
            alive: 4,
            gamma: Some(3),
            grad_norm: 0.7,
            recoveries: 1,
            rollback_iters: 4,
        });
        let path = std::env::temp_dir().join("hybriditer_csv_test/x.csv");
        write_recorder(&rec, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        let header = lines.next().unwrap();
        assert!(header.starts_with("iter,time,loss"));
        assert!(header.contains("stale,dropped,duplicated"));
        assert!(header.ends_with(",blocks,stale_blocks,recoveries,rollback_iters"));
        let row = lines.next().unwrap();
        assert!(row.starts_with("0,0.5,2,2.1,,3,1,2,5,1,4,3,0.7"));
        assert!(row.ends_with(",6,2,1,4"));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn table_escaping() {
        let path = std::env::temp_dir().join("hybriditer_csv_test/t.csv");
        write_table(
            &["a", "b"],
            &[vec!["x,y".to_string(), "plain".to_string()]],
            &path,
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"x,y\",plain"));
        std::fs::remove_file(&path).unwrap();
    }
}
