#!/usr/bin/env python3
"""Validate a flight-recorder JSONL journal (docs/OBSERVABILITY.md).

Checks, per line:
  * the line is a well-formed JSON object;
  * the envelope fields are present and correctly typed
    (seq/iter: non-negative ints, worker: int >= -1, time: finite number);
  * the event name is a known member of the taxonomy.

Checks, across the journal:
  * `seq` starts at 0 and is strictly increasing by 1 (a gap means a sink
    dropped or reordered an event);
  * the journal is non-empty.

Exit code 0 on success; 1 with a line-numbered error otherwise.

Usage: scripts/check_trace.py <journal.jsonl>
"""

import json
import math
import sys

KNOWN_EVENTS = {
    "dispatch",
    "delivery",
    "drop",
    "duplicate",
    "block_fate",
    "stale_admission",
    "retry_attempt",
    "rebalance_cut",
    "join",
    "leave",
    "crash",
    "barrier_close",
    "recovery_start",
    "recovery_done",
    "agg_fold",
    "forward",
    "serve_window",
    "theta_publish",
}


def fail(lineno, msg):
    print(f"::error::trace journal line {lineno}: {msg}")
    sys.exit(1)


def main(path):
    counts = {}
    expected_seq = 0
    with open(path, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, start=1):
            line = line.strip()
            if not line:
                fail(lineno, "blank line in journal")
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                fail(lineno, f"not valid JSON: {e}")
            if not isinstance(rec, dict):
                fail(lineno, "record is not a JSON object")
            for key in ("seq", "iter", "worker", "time", "event"):
                if key not in rec:
                    fail(lineno, f"missing field {key!r}")
            if not isinstance(rec["seq"], int) or rec["seq"] < 0:
                fail(lineno, f"bad seq {rec['seq']!r}")
            if rec["seq"] != expected_seq:
                fail(lineno, f"seq {rec['seq']} breaks the strict 0,1,2,... order")
            expected_seq += 1
            if not isinstance(rec["iter"], int) or rec["iter"] < 0:
                fail(lineno, f"bad iter {rec['iter']!r}")
            if not isinstance(rec["worker"], int) or rec["worker"] < -1:
                fail(lineno, f"bad worker {rec['worker']!r} (master is -1)")
            t = rec["time"]
            if not isinstance(t, (int, float)) or isinstance(t, bool) or not math.isfinite(t):
                fail(lineno, f"bad time {t!r}")
            ev = rec["event"]
            if ev not in KNOWN_EVENTS:
                fail(lineno, f"unknown event {ev!r}")
            counts[ev] = counts.get(ev, 0) + 1
    if expected_seq == 0:
        print(f"::error::trace journal {path} is empty")
        sys.exit(1)
    summary = ", ".join(f"{k}={v}" for k, v in sorted(counts.items()))
    print(f"{path}: {expected_seq} events OK ({summary})")


if __name__ == "__main__":
    if len(sys.argv) != 2:
        print(__doc__)
        sys.exit(2)
    main(sys.argv[1])
