//! Self-healing recovery policies: what to do when a worker dies.
//!
//! The source paper's answer to failure is to *abandon* a dead worker's
//! results; the repo additionally *rebalances* its shards at elastic
//! boundaries.  This module adds the remaining two corners of the
//! design space from Qiao et al. (*Fault Tolerance in
//! Iterative-Convergent ML*): **partial recovery** — keep iterating
//! through the crash and reconstruct only the lost partition's
//! contribution, no global rollback — and **checkpoint-restore** —
//! periodic θ snapshots with restore-on-crash and explicit rollback
//! accounting.
//!
//! Both drivers (the virtual `sim/` engine and the threaded
//! `coordinator`/`worker` runtime) consult one [`RecoveryState`] at the
//! same crash/leave/join boundaries, so recovery decisions are pure
//! functions of the scheduled trace and the failure RNG — like network
//! fates — and the trace-parity oracles extend naturally: under
//! scheduled elastic traces both drivers journal identical
//! `RecoveryStart`/`RecoveryDone` sequences (see `docs/RECOVERY.md`).
//!
//! The policy taxonomy:
//!
//! * [`RecoveryPolicy::Abandon`] — the paper's baseline and the
//!   default: lost contributions are simply abandoned.  This is the
//!   exact pre-recovery behaviour, bit for bit, with zero additional
//!   work on the hot path.
//! * [`RecoveryPolicy::Rebalance`] — abandon the lost contribution but
//!   force a shard replan whenever membership is perturbed, even when
//!   periodic rebalancing (`[elastic] rebalance_every`) is off.
//! * [`RecoveryPolicy::PartialRecovery`] — keep iterating; when the
//!   worker respawns or its scheduled join lands, recompute its
//!   partition's gradient at the *current* θ and fold it through the
//!   staleness-damped aggregation path with staleness = downtime.
//! * [`RecoveryPolicy::CheckpointRestore`] — snapshot θ every
//!   `checkpoint_every` iterations (in memory, via the
//!   [`crate::data::Checkpoint`] container); on a crash, restore θ from
//!   the last snapshot and account the rolled-back iterations.

use crate::data::Checkpoint;
use crate::{Error, Result};

/// What the run does when a worker crashes or leaves.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecoveryPolicy {
    /// Abandon the lost contribution (the paper's behaviour; default).
    Abandon,
    /// Abandon, but force a shard replan on every membership change.
    Rebalance,
    /// Keep iterating; reconstruct only the lost partition on rejoin.
    PartialRecovery,
    /// Periodic θ snapshots; restore-on-crash with rollback accounting.
    CheckpointRestore,
}

impl RecoveryPolicy {
    /// Parse a policy name as written in config / CLI.
    pub fn parse(s: &str) -> Result<RecoveryPolicy> {
        Ok(match s {
            "abandon" => RecoveryPolicy::Abandon,
            "rebalance" => RecoveryPolicy::Rebalance,
            "partial-recovery" => RecoveryPolicy::PartialRecovery,
            "checkpoint-restore" => RecoveryPolicy::CheckpointRestore,
            other => {
                return Err(Error::Config(format!(
                    "unknown recovery policy '{other}' \
                     (abandon|rebalance|partial-recovery|checkpoint-restore)"
                )))
            }
        })
    }

    /// The canonical name (inverse of [`RecoveryPolicy::parse`]); also
    /// the `policy` payload of `recovery_start`/`recovery_done` events.
    pub fn name(self) -> &'static str {
        match self {
            RecoveryPolicy::Abandon => "abandon",
            RecoveryPolicy::Rebalance => "rebalance",
            RecoveryPolicy::PartialRecovery => "partial-recovery",
            RecoveryPolicy::CheckpointRestore => "checkpoint-restore",
        }
    }

    /// Does the supervisor auto-respawn stochastically crashed workers
    /// at the next iteration boundary?  (Scheduled leaves are *never*
    /// auto-respawned — a scripted departure is not a failure.)
    pub fn respawns_crashed(self) -> bool {
        matches!(
            self,
            RecoveryPolicy::PartialRecovery | RecoveryPolicy::CheckpointRestore
        )
    }

    /// Does the policy force a shard replan when membership changes?
    pub fn forces_rebalance(self) -> bool {
        self == RecoveryPolicy::Rebalance
    }

    /// Does the policy take periodic θ snapshots?
    pub fn checkpoints(self) -> bool {
        self == RecoveryPolicy::CheckpointRestore
    }

    /// Does the policy queue a lost-partition catch-up on rejoin?
    pub fn catches_up(self) -> bool {
        self == RecoveryPolicy::PartialRecovery
    }
}

/// `[recovery]` config section (+ `--recovery-policy` /
/// `--checkpoint-every` CLI overrides).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RecoveryConfig {
    pub policy: RecoveryPolicy,
    /// Snapshot cadence for [`RecoveryPolicy::CheckpointRestore`]
    /// (iterations between in-memory θ checkpoints).
    pub checkpoint_every: u64,
}

impl Default for RecoveryConfig {
    fn default() -> RecoveryConfig {
        RecoveryConfig { policy: RecoveryPolicy::Abandon, checkpoint_every: 25 }
    }
}

impl RecoveryConfig {
    pub fn validate(&self) -> Result<()> {
        if self.policy.checkpoints() && self.checkpoint_every == 0 {
            return Err(Error::Config(
                "recovery.checkpoint_every must be > 0 under checkpoint-restore".into(),
            ));
        }
        Ok(())
    }
}

/// A queued lost-partition reconstruction: recompute worker `worker`'s
/// current shards at the live θ and fold them with `staleness` =
/// iterations of downtime (the staleness-damped aggregator weights the
/// catch-up by `rho^staleness`; the plain mean folds it unweighted).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CatchUp {
    pub worker: usize,
    pub staleness: u64,
}

/// Per-run recovery state, shared verbatim by both drivers.
///
/// The drivers call the hooks at fixed points of the iteration:
///
/// 1. [`take_respawns`](RecoveryState::take_respawns) — top of the
///    iteration, before the elastic boundary: workers that crashed
///    stochastically last iteration respawn (ascending worker order).
/// 2. [`maybe_snapshot`](RecoveryState::maybe_snapshot) — a due θ
///    checkpoint is taken *before* boundary events and the failure
///    sweep, so a same-iteration crash restores to this snapshot.
/// 3. [`on_leave`](RecoveryState::on_leave) /
///    [`on_join`](RecoveryState::on_join) — per scheduled elastic
///    event, inside the boundary, in schedule order.
/// 4. [`on_crash`](RecoveryState::on_crash) — when the stochastic
///    failure model kills a worker (virtual failure sweep; threaded
///    `SimulatedCrash` message).
/// 5. [`take_catchups`](RecoveryState::take_catchups) — at gradient
///    aggregation, appended after the fresh/carryover/stale chains.
///
/// A hook returning `Some(rollback)` means a recovery fired: the caller
/// journals a `RecoveryStart`/`RecoveryDone` pair for that worker (see
/// [`crate::trace::emit_recovery`]) and the state has already counted
/// it in [`recoveries`](RecoveryState::recoveries) /
/// [`rollback_iters`](RecoveryState::rollback_iters).
#[derive(Debug)]
pub struct RecoveryState {
    cfg: RecoveryConfig,
    /// Iteration at which each worker went down (`None` = up).
    down_at: Vec<Option<u64>>,
    /// Stochastically crashed workers awaiting supervisor respawn.
    respawn_queue: Vec<usize>,
    /// Lost-partition reconstructions awaiting the aggregation phase.
    catchup_queue: Vec<CatchUp>,
    /// Last θ snapshot (checkpoint-restore only).
    snapshot: Option<Checkpoint>,
    /// Membership was perturbed since the last boundary replan
    /// (rebalance policy only).
    force_replan: bool,
    /// Total recoveries fired.
    pub recoveries: u64,
    /// Total iterations rolled back across all restores.
    pub rollback_iters: u64,
}

impl RecoveryState {
    pub fn new(cfg: RecoveryConfig, workers: usize) -> RecoveryState {
        RecoveryState {
            cfg,
            down_at: vec![None; workers],
            respawn_queue: Vec::new(),
            catchup_queue: Vec::new(),
            snapshot: None,
            force_replan: false,
            recoveries: 0,
            rollback_iters: 0,
        }
    }

    pub fn policy(&self) -> RecoveryPolicy {
        self.cfg.policy
    }

    /// True for the default policy: every hook is a no-op and the
    /// drivers keep their pre-recovery hot paths (zero allocations per
    /// steady-state virtual iteration).
    pub fn is_noop(&self) -> bool {
        self.cfg.policy == RecoveryPolicy::Abandon
    }

    /// Take a θ snapshot if one is due this iteration.
    pub fn maybe_snapshot(&mut self, iter: u64, theta: &[f32]) {
        if self.cfg.policy.checkpoints() && iter % self.cfg.checkpoint_every == 0 {
            match &mut self.snapshot {
                // Reuse the buffer: snapshots are hot-loop work.
                Some(ck) => {
                    ck.theta.clear();
                    ck.theta.extend_from_slice(theta);
                    ck.iter = iter;
                }
                None => self.snapshot = Some(Checkpoint::new(theta.to_vec(), iter)),
            }
        }
    }

    /// Iteration of the last snapshot, if any.
    pub fn snapshot_iter(&self) -> Option<u64> {
        self.snapshot.as_ref().map(|c| c.iter)
    }

    /// Drain the supervisor respawn queue (ascending worker order) into
    /// `out`.  The driver re-admits each worker
    /// (`FailureState::force_rejoin` + `Membership::mark_alive`, or an
    /// actual thread respawn) and then calls
    /// [`on_join`](RecoveryState::on_join) for it.
    pub fn take_respawns(&mut self, out: &mut Vec<usize>) {
        out.clear();
        out.append(&mut self.respawn_queue);
        out.sort_unstable();
    }

    /// Drain queued lost-partition catch-ups into `out`.
    pub fn take_catchups(&mut self, out: &mut Vec<CatchUp>) {
        out.clear();
        out.append(&mut self.catchup_queue);
    }

    /// Consume the forced-replan flag (rebalance policy).  The boundary
    /// treats a set flag as "a rebalance is due now" regardless of the
    /// periodic `rebalance_every` cadence.
    pub fn take_force_replan(&mut self) -> bool {
        std::mem::take(&mut self.force_replan)
    }

    /// The stochastic failure model killed worker `w` at `iter`.
    /// Returns `Some(rollback)` when a recovery fired (journal it).
    pub fn on_crash(&mut self, w: usize, iter: u64, theta: &mut [f32]) -> Option<u64> {
        if self.cfg.policy.respawns_crashed() {
            self.respawn_queue.push(w);
        }
        self.on_down(w, iter, theta)
    }

    /// A scheduled elastic leave removed worker `w` at `iter`.  Same
    /// recovery semantics as a crash, but never queues a respawn — a
    /// scripted departure is immune to the supervisor.
    pub fn on_leave(&mut self, w: usize, iter: u64, theta: &mut [f32]) -> Option<u64> {
        self.on_down(w, iter, theta)
    }

    fn on_down(&mut self, w: usize, iter: u64, theta: &mut [f32]) -> Option<u64> {
        if self.down_at[w].is_none() {
            self.down_at[w] = Some(iter);
        }
        match self.cfg.policy {
            RecoveryPolicy::Abandon => None,
            RecoveryPolicy::Rebalance => {
                self.force_replan = true;
                self.recoveries += 1;
                Some(0)
            }
            // Partial recovery's work happens at rejoin; the downtime
            // start is all that is recorded here.
            RecoveryPolicy::PartialRecovery => None,
            RecoveryPolicy::CheckpointRestore => {
                let rollback = match &self.snapshot {
                    Some(ck) => {
                        theta.copy_from_slice(&ck.theta);
                        iter - ck.iter
                    }
                    // No snapshot yet (crash before the first cadence
                    // point): nothing to restore, zero rollback.
                    None => 0,
                };
                self.recoveries += 1;
                self.rollback_iters += rollback;
                Some(rollback)
            }
        }
    }

    /// Worker `w` rejoined at `iter` — scheduled join or supervisor
    /// respawn.  Returns `Some(rollback)` when a recovery fired.
    pub fn on_join(&mut self, w: usize, iter: u64) -> Option<u64> {
        let down_at = self.down_at[w].take();
        match self.cfg.policy {
            RecoveryPolicy::Abandon | RecoveryPolicy::CheckpointRestore => None,
            RecoveryPolicy::Rebalance => {
                self.force_replan = true;
                self.recoveries += 1;
                Some(0)
            }
            RecoveryPolicy::PartialRecovery => {
                // A join with no recorded downtime (a brand-new worker)
                // has no lost contribution to reconstruct.
                let start = down_at?;
                self.catchup_queue.push(CatchUp {
                    worker: w,
                    staleness: iter.saturating_sub(start),
                });
                self.recoveries += 1;
                Some(0)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_and_rejects() {
        for p in [
            RecoveryPolicy::Abandon,
            RecoveryPolicy::Rebalance,
            RecoveryPolicy::PartialRecovery,
            RecoveryPolicy::CheckpointRestore,
        ] {
            assert_eq!(RecoveryPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(RecoveryPolicy::parse("wormhole").is_err());
    }

    #[test]
    fn config_validates_checkpoint_cadence() {
        let bad = RecoveryConfig {
            policy: RecoveryPolicy::CheckpointRestore,
            checkpoint_every: 0,
        };
        assert!(bad.validate().is_err());
        // A zero cadence is fine when nothing checkpoints.
        let ok = RecoveryConfig { policy: RecoveryPolicy::Abandon, checkpoint_every: 0 };
        assert!(ok.validate().is_ok());
        assert!(RecoveryConfig::default().validate().is_ok());
        assert_eq!(RecoveryConfig::default().policy, RecoveryPolicy::Abandon);
    }

    #[test]
    fn abandon_is_noop() {
        let mut st = RecoveryState::new(RecoveryConfig::default(), 4);
        assert!(st.is_noop());
        let mut theta = vec![1.0f32; 4];
        st.maybe_snapshot(0, &theta);
        assert!(st.snapshot_iter().is_none());
        assert_eq!(st.on_crash(1, 3, &mut theta), None);
        assert_eq!(st.on_leave(2, 3, &mut theta), None);
        assert_eq!(st.on_join(1, 5), None);
        let mut out = Vec::new();
        st.take_respawns(&mut out);
        assert!(out.is_empty());
        assert_eq!(st.recoveries, 0);
        assert_eq!(st.rollback_iters, 0);
        assert_eq!(theta, vec![1.0f32; 4]);
    }

    #[test]
    fn rebalance_counts_and_forces_replan() {
        let cfg = RecoveryConfig { policy: RecoveryPolicy::Rebalance, ..Default::default() };
        let mut st = RecoveryState::new(cfg, 4);
        let mut theta = vec![0.0f32; 2];
        assert_eq!(st.on_leave(0, 2, &mut theta), Some(0));
        assert!(st.take_force_replan());
        assert!(!st.take_force_replan());
        assert_eq!(st.on_join(0, 6), Some(0));
        assert!(st.take_force_replan());
        assert_eq!(st.recoveries, 2);
        assert_eq!(st.rollback_iters, 0);
        // Rebalance never respawns.
        assert_eq!(st.on_crash(1, 7, &mut theta), Some(0));
        let mut out = Vec::new();
        st.take_respawns(&mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn partial_recovery_queues_catchup_with_downtime_staleness() {
        let cfg =
            RecoveryConfig { policy: RecoveryPolicy::PartialRecovery, ..Default::default() };
        let mut st = RecoveryState::new(cfg, 4);
        let mut theta = vec![0.0f32; 2];
        // Crash queues a respawn but fires no recovery yet.
        assert_eq!(st.on_crash(2, 3, &mut theta), None);
        let mut spawns = Vec::new();
        st.take_respawns(&mut spawns);
        assert_eq!(spawns, vec![2]);
        // The rejoin reconstructs 4 - 3 = 1 iteration of downtime.
        assert_eq!(st.on_join(2, 4), Some(0));
        let mut cs = Vec::new();
        st.take_catchups(&mut cs);
        assert_eq!(cs, vec![CatchUp { worker: 2, staleness: 1 }]);
        assert_eq!(st.recoveries, 1);
        // A brand-new joiner has nothing to reconstruct.
        assert_eq!(st.on_join(3, 9), None);
        st.take_catchups(&mut cs);
        assert!(cs.is_empty());
        assert_eq!(st.recoveries, 1);
    }

    #[test]
    fn checkpoint_restore_rolls_back_to_last_snapshot() {
        let cfg = RecoveryConfig {
            policy: RecoveryPolicy::CheckpointRestore,
            checkpoint_every: 5,
        };
        let mut st = RecoveryState::new(cfg, 4);
        let mut theta = vec![1.0f32, 2.0];
        st.maybe_snapshot(0, &theta);
        assert_eq!(st.snapshot_iter(), Some(0));
        // Off-cadence iterations do not snapshot.
        theta = vec![3.0, 4.0];
        st.maybe_snapshot(3, &theta);
        assert_eq!(st.snapshot_iter(), Some(0));
        st.maybe_snapshot(5, &theta);
        assert_eq!(st.snapshot_iter(), Some(5));
        // Crash at 8 restores the iter-5 snapshot: rollback 3.
        theta = vec![9.0, 9.0];
        assert_eq!(st.on_crash(1, 8, &mut theta), Some(3));
        assert_eq!(theta, vec![3.0, 4.0]);
        assert_eq!(st.recoveries, 1);
        assert_eq!(st.rollback_iters, 3);
        // The crashed worker respawns; the rejoin itself fires nothing.
        let mut spawns = Vec::new();
        st.take_respawns(&mut spawns);
        assert_eq!(spawns, vec![1]);
        assert_eq!(st.on_join(1, 9), None);
        // Rollback never exceeds the snapshot cadence.
        assert!(st.rollback_iters < 5);
    }

    #[test]
    fn crash_before_first_snapshot_restores_nothing() {
        let cfg = RecoveryConfig {
            policy: RecoveryPolicy::CheckpointRestore,
            checkpoint_every: 10,
        };
        let mut st = RecoveryState::new(cfg, 2);
        let mut theta = vec![7.0f32];
        assert_eq!(st.on_crash(0, 4, &mut theta), Some(0));
        assert_eq!(theta, vec![7.0f32]);
        assert_eq!(st.rollback_iters, 0);
    }

    #[test]
    fn respawns_drain_in_ascending_worker_order() {
        let cfg =
            RecoveryConfig { policy: RecoveryPolicy::PartialRecovery, ..Default::default() };
        let mut st = RecoveryState::new(cfg, 8);
        let mut theta = vec![0.0f32];
        st.on_crash(5, 1, &mut theta);
        st.on_crash(2, 1, &mut theta);
        st.on_crash(7, 1, &mut theta);
        let mut out = Vec::new();
        st.take_respawns(&mut out);
        assert_eq!(out, vec![2, 5, 7]);
    }
}
