//! The whole cluster's network specification and its pure per-message
//! realization function.
//!
//! Purity is a load-bearing property: both drivers *and* the flight
//! recorder ([`crate::trace::emit_roundtrip_fates`]) realize each message's
//! fate independently from the same key, and the trace-parity oracles in
//! `tests/parity_drivers.rs` rely on those realizations agreeing exactly.

use super::block::{BlockSet, MAX_BLOCKS};
use super::link::{LinkModel, LinkRealization};
use crate::util::rng::Pcg64;
use crate::{Error, Result};

/// Salt mixed into the per-block fate stream so block fates are
/// independent of the legacy per-message stream (which must keep
/// realizing bit-identically with blocking off).
const BLOCK_SALT: u64 = 0xB10C_FA7E_0000_0001;
/// Salt for a duplicated reply's block set — an independent retransmission
/// realization, so a dup can carry blocks its primary lost (and overlap
/// with blocks it delivered, which is what the dedup ledger guards).
const BLOCK_DUP_SALT: u64 = 0xB10C_D0B1_0000_0002;
/// Salt for BSP retry attempts, keyed additionally by the attempt index.
const RETRY_SALT: u64 = 0x8E72_4A11_0000_0003;
/// Salt for interior aggregation-topology edges (tree forwards, ring
/// hops), keyed additionally by a per-topology round index.
const EDGE_SALT: u64 = 0xED6E_F01D_0000_0004;

/// A scripted partition: the named workers are unreachable — both
/// directions dropped — for iterations `from..until` (half-open, like the
/// `a..b` syntax it parses from).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partition {
    pub workers: Vec<usize>,
    pub from: u64,
    pub until: u64,
}

impl Partition {
    pub fn covers(&self, worker: usize, iter: u64) -> bool {
        iter >= self.from && iter < self.until && self.workers.contains(&worker)
    }
}

/// The coordinator↔worker network: a default [`LinkModel`], per-worker
/// overrides for asymmetric topologies, and scripted partition windows.
/// [`NetSpec::ideal`] (the default) reproduces pre-transport behaviour
/// bit for bit.
#[derive(Clone, Debug, PartialEq)]
pub struct NetSpec {
    /// Link personality applied to every worker without an override.
    pub default_link: LinkModel,
    /// `(worker, model)` overrides — e.g. one chronically slow/lossy link.
    pub overrides: Vec<(usize, LinkModel)>,
    /// Scripted partition windows.
    pub partitions: Vec<Partition>,
    /// Extra salt mixed into the per-message streams, so two specs can
    /// realize differently under one cluster seed.
    pub salt: u64,
    /// Gradient block size (coordinates per block) for partial admission.
    /// `0` (the default) disables blocking: every reply is one block and
    /// admission stays the legacy binary decision, bit for bit.
    pub block_size: usize,
    /// Minimum delivered fraction a blocked reply needs to be admitted;
    /// below it the reply counts as a network drop (the async drivers
    /// retransmit, the sync drivers never surface it).  Only meaningful
    /// when blocking is active.
    pub min_block_frac: f64,
}

impl Default for NetSpec {
    fn default() -> Self {
        NetSpec::ideal()
    }
}

impl NetSpec {
    /// Perfect network — the seed system's implicit assumption.
    pub fn ideal() -> NetSpec {
        NetSpec {
            default_link: LinkModel::ideal(),
            overrides: Vec::new(),
            partitions: Vec::new(),
            salt: 0,
            block_size: 0,
            min_block_frac: 0.0,
        }
    }

    /// Zero-latency network losing each message with probability `p`.
    pub fn lossy(p: f64) -> NetSpec {
        NetSpec { default_link: LinkModel::lossy(p), ..NetSpec::ideal() }
    }

    pub fn is_ideal(&self) -> bool {
        self.partitions.is_empty()
            && self.default_link.is_ideal()
            && self.overrides.iter().all(|(_, l)| l.is_ideal())
    }

    /// Builder: partition `workers` away for iterations `from..until`.
    pub fn with_partition(mut self, workers: &[usize], from: u64, until: u64) -> Self {
        self.partitions.push(Partition { workers: workers.to_vec(), from, until });
        self
    }

    /// Builder: give one worker's link its own model.
    pub fn with_override(mut self, worker: usize, link: LinkModel) -> Self {
        self.overrides.push((worker, link));
        self
    }

    /// The model governing worker `w`'s link.
    pub fn link_for(&self, worker: usize) -> &LinkModel {
        self.overrides
            .iter()
            .find(|(w, _)| *w == worker)
            .map(|(_, l)| l)
            .unwrap_or(&self.default_link)
    }

    /// Is worker `w` inside a scripted partition window at `iter`?
    pub fn partitioned(&self, worker: usize, iter: u64) -> bool {
        self.partitions.iter().any(|p| p.covers(worker, iter))
    }

    pub fn validate(&self, workers: usize) -> Result<()> {
        self.default_link.validate()?;
        for (w, link) in &self.overrides {
            if *w >= workers {
                return Err(Error::Cluster(format!(
                    "net override names worker {w} but cluster has {workers}"
                )));
            }
            link.validate()?;
        }
        if !(0.0..=1.0).contains(&self.min_block_frac) {
            return Err(Error::Config(format!(
                "net min_block_frac must be in [0, 1], got {}",
                self.min_block_frac
            )));
        }
        for p in &self.partitions {
            if p.from >= p.until {
                return Err(Error::Config(format!(
                    "partition window {}..{} is empty",
                    p.from, p.until
                )));
            }
            for &w in &p.workers {
                if w >= workers {
                    return Err(Error::Cluster(format!(
                        "partition names worker {w} but cluster has {workers}"
                    )));
                }
            }
        }
        Ok(())
    }

    /// Realize worker `worker`'s iteration-`iter` roundtrip.  A **pure
    /// function** of `(seed, worker, iter)` (plus the spec itself): both
    /// drivers call this with the same arguments and get the same fates
    /// and delays, which is the whole cross-driver parity guarantee.
    pub fn realize(&self, seed: u64, worker: usize, iter: u64) -> LinkRealization {
        if self.is_ideal() {
            return LinkRealization::ideal();
        }
        if self.partitioned(worker, iter) {
            return LinkRealization::partitioned();
        }
        // One independent PCG stream per (worker, iter) message pair; the
        // stream id mixes both so consumption order cannot couple streams.
        let stream = (worker as u64 + 1)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ iter.wrapping_mul(0xD1B5_4A32_D192_ED03);
        let mut rng = Pcg64::new(seed ^ self.salt.wrapping_mul(0xA076_1D64_78BD_642F), stream);
        self.link_for(worker).realize(&mut rng)
    }

    /// How many blocks a `dim`-coordinate gradient chunks into under this
    /// spec.  `1` means blocking is off (the legacy single-block model);
    /// the count caps at [`MAX_BLOCKS`] so the delivered set stays a
    /// single-word mask.
    pub fn n_blocks(&self, dim: usize) -> usize {
        if self.block_size == 0 || dim == 0 {
            1
        } else {
            dim.div_ceil(self.block_size).clamp(1, MAX_BLOCKS)
        }
    }

    /// Admission policy for a blocked reply's delivered set: non-empty and
    /// at least `min_block_frac` of the blocks present.
    pub fn admits(&self, blocks: BlockSet) -> bool {
        !blocks.is_empty() && blocks.fraction() >= self.min_block_frac
    }

    /// Realize which of a reply's `n` blocks survive the uplink — a pure
    /// function of `(seed, worker, iter, duplicate)` plus the spec, like
    /// [`NetSpec::realize`], from an independently-salted stream so the
    /// legacy per-message realization is untouched.
    ///
    /// Block 0 rides the legacy up-direction fate (`up_dropped`), so a
    /// single-block reply reproduces the binary decision exactly; blocks
    /// `1..n` each sample the link's effective uplink drop probability.
    /// A duplicated copy is an independent retransmission realization:
    /// its block 0 always lands (the copy exists because the primary
    /// delivered) and its tail blocks draw from a dup-salted stream, so
    /// dup sets can overlap the primary's — the [`super::BlockLedger`]
    /// dedup guard is what keeps overlaps from double-counting.
    pub fn realize_blocks(
        &self,
        seed: u64,
        worker: usize,
        iter: u64,
        n: usize,
        up_dropped: bool,
        duplicate: bool,
    ) -> BlockSet {
        if n <= 1 {
            return if up_dropped && !duplicate {
                BlockSet::empty(1)
            } else {
                BlockSet::full(1)
            };
        }
        if self.partitioned(worker, iter) {
            return BlockSet::empty(n);
        }
        let mut set = BlockSet::empty(n);
        if duplicate || !up_dropped {
            set = set.with(0);
        }
        let (_, up_drop) = self.link_for(worker).up_dir();
        let stream = (worker as u64 + 1)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ iter.wrapping_mul(0xD1B5_4A32_D192_ED03);
        let salt = if duplicate { BLOCK_DUP_SALT } else { BLOCK_SALT };
        let mut rng =
            Pcg64::new(seed ^ self.salt.wrapping_mul(0xA076_1D64_78BD_642F) ^ salt, stream);
        for b in 1..n {
            if rng.next_f64() >= up_drop {
                set = set.with(b);
            }
        }
        set
    }

    /// Realize a BSP retry's retransmission roundtrip: attempt `attempt`
    /// of worker `worker`'s iteration-`iter` recovery.  Pure in
    /// `(seed, worker, iter, attempt)` and independent of the primary
    /// message stream, so routing retries through the link model cannot
    /// perturb any non-retry realization.
    pub fn realize_attempt(
        &self,
        seed: u64,
        worker: usize,
        iter: u64,
        attempt: u64,
    ) -> LinkRealization {
        if self.is_ideal() {
            return LinkRealization::ideal();
        }
        if self.partitioned(worker, iter) {
            return LinkRealization::partitioned();
        }
        let stream = (worker as u64 + 1)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ iter.wrapping_mul(0xD1B5_4A32_D192_ED03)
            ^ attempt.wrapping_add(1).wrapping_mul(0xC2B2_AE3D_27D4_EB4F);
        let mut rng = Pcg64::new(
            seed ^ self.salt.wrapping_mul(0xA076_1D64_78BD_642F) ^ RETRY_SALT,
            stream,
        );
        self.link_for(worker).realize(&mut rng)
    }

    /// Realize one interior aggregation-topology edge: node `node`'s
    /// round-`round` forward along the overlay (a tree relay's combined
    /// uplink, or one ring hop).  Pure in `(seed, node, iter, round)` and
    /// drawn from an independently-salted stream, so routing aggregation
    /// through the link model cannot perturb any leaf realization — the
    /// star path keeps reproducing bit for bit.  The edge inherits the
    /// sending node's [`LinkModel`] (per-worker overrides shape the
    /// overlay edges rooted at that worker).
    pub fn realize_edge(&self, seed: u64, node: usize, iter: u64, round: u64) -> LinkRealization {
        if self.is_ideal() {
            return LinkRealization::ideal();
        }
        if self.partitioned(node, iter) {
            return LinkRealization::partitioned();
        }
        let stream = (node as u64 + 1)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ iter.wrapping_mul(0xD1B5_4A32_D192_ED03)
            ^ round.wrapping_add(1).wrapping_mul(0xC2B2_AE3D_27D4_EB4F);
        let mut rng = Pcg64::new(
            seed ^ self.salt.wrapping_mul(0xA076_1D64_78BD_642F) ^ EDGE_SALT,
            stream,
        );
        self.link_for(node).realize(&mut rng)
    }

    /// Parse the partition script syntax: `;`-separated windows
    /// `<workers>@<from>..<until>`, where `<workers>` is a comma-separated
    /// mix of indices and inclusive `a-b` ranges.  Example:
    /// `"3-5@40..60;0@10..20"`.  An empty string parses to no partitions.
    pub fn parse_partitions(text: &str) -> Result<Vec<Partition>> {
        let bad = |term: &str| {
            Error::Config(format!(
                "bad partition '{term}' (want workers@from..until, e.g. 3-5@40..60)"
            ))
        };
        let mut out = Vec::new();
        for term in text.split(';').map(str::trim).filter(|t| !t.is_empty()) {
            let (workers, span) = term.split_once('@').ok_or_else(|| bad(term))?;
            let (from, until) = span.split_once("..").ok_or_else(|| bad(term))?;
            let mut ws: Vec<usize> = Vec::new();
            for part in workers.split(',').map(str::trim).filter(|p| !p.is_empty()) {
                match part.split_once('-') {
                    Some((a, b)) => {
                        let a: usize = a.trim().parse().map_err(|_| bad(term))?;
                        let b: usize = b.trim().parse().map_err(|_| bad(term))?;
                        if a > b {
                            return Err(bad(term));
                        }
                        ws.extend(a..=b);
                    }
                    None => ws.push(part.parse().map_err(|_| bad(term))?),
                }
            }
            if ws.is_empty() {
                return Err(bad(term));
            }
            out.push(Partition {
                workers: ws,
                from: from.trim().parse().map_err(|_| bad(term))?,
                until: until.trim().parse().map_err(|_| bad(term))?,
            });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::straggler::DelayModel;

    #[test]
    fn realize_is_pure_and_varies_by_message() {
        let spec = NetSpec::lossy(0.4);
        let a = spec.realize(7, 2, 13);
        let b = spec.realize(7, 2, 13);
        assert_eq!(a, b, "same (seed, worker, iter) must realize identically");
        // Across iterations / workers the fates must actually vary.
        let mut distinct = false;
        for iter in 0..64 {
            if spec.realize(7, 2, iter) != a {
                distinct = true;
                break;
            }
        }
        assert!(distinct, "lossy realizations never varied");
    }

    #[test]
    fn ideal_realizes_ideal_without_sampling() {
        let spec = NetSpec::ideal();
        assert!(spec.is_ideal());
        for w in 0..4 {
            for iter in 0..16 {
                assert_eq!(spec.realize(1, w, iter), LinkRealization::ideal());
            }
        }
    }

    #[test]
    fn partition_window_kills_both_directions() {
        let spec = NetSpec::ideal().with_partition(&[1, 2], 10, 20);
        assert!(!spec.is_ideal());
        assert_eq!(spec.realize(5, 1, 9), LinkRealization::ideal());
        assert_eq!(spec.realize(5, 1, 10), LinkRealization::partitioned());
        assert_eq!(spec.realize(5, 2, 19), LinkRealization::partitioned());
        assert_eq!(spec.realize(5, 2, 20), LinkRealization::ideal());
        assert_eq!(spec.realize(5, 0, 15), LinkRealization::ideal());
    }

    #[test]
    fn override_shapes_one_link() {
        let slow = LinkModel {
            latency: DelayModel::Constant { secs: 0.05 },
            ..LinkModel::ideal()
        };
        let spec = NetSpec::ideal().with_override(3, slow.clone());
        assert_eq!(spec.link_for(3), &slow);
        assert_eq!(spec.link_for(0), &LinkModel::ideal());
        let r = spec.realize(1, 3, 0);
        assert!((r.roundtrip_delay() - 0.10).abs() < 1e-12);
        assert_eq!(spec.realize(1, 0, 0), LinkRealization::ideal());
    }

    #[test]
    fn salt_changes_realizations() {
        let a = NetSpec::lossy(0.5);
        let b = NetSpec { salt: 1, ..NetSpec::lossy(0.5) };
        let differs = (0..64).any(|i| a.realize(9, 0, i) != b.realize(9, 0, i));
        assert!(differs);
    }

    #[test]
    fn parse_partitions_grammar() {
        let ps = NetSpec::parse_partitions("3-5@40..60; 0@10..20").unwrap();
        assert_eq!(ps.len(), 2);
        assert_eq!(ps[0].workers, vec![3, 4, 5]);
        assert_eq!((ps[0].from, ps[0].until), (40, 60));
        assert_eq!(ps[1].workers, vec![0]);
        let ps = NetSpec::parse_partitions("0,2-3,7@1..2").unwrap();
        assert_eq!(ps[0].workers, vec![0, 2, 3, 7]);
        assert!(NetSpec::parse_partitions("").unwrap().is_empty());
    }

    #[test]
    fn parse_partitions_rejects_garbage() {
        assert!(NetSpec::parse_partitions("nope").is_err());
        assert!(NetSpec::parse_partitions("1@5").is_err());
        assert!(NetSpec::parse_partitions("5-3@1..2").is_err());
        assert!(NetSpec::parse_partitions("x@1..2").is_err());
        assert!(NetSpec::parse_partitions("@1..2").is_err());
    }

    #[test]
    fn block_realization_is_pure_and_reduces_to_legacy() {
        use crate::net::block::BlockSet;
        let spec = NetSpec { block_size: 2, ..NetSpec::lossy(0.3) };
        for w in 0..4usize {
            for iter in 0..32u64 {
                let r = spec.realize(9, w, iter);
                let a = spec.realize_blocks(9, w, iter, 8, r.up_dropped, false);
                let b = spec.realize_blocks(9, w, iter, 8, r.up_dropped, false);
                assert_eq!(a, b, "block fates must be pure");
                // Block 0 carries the legacy up fate.
                assert_eq!(a.contains(0), !r.up_dropped);
                // Single block ≡ the binary decision.
                let one = spec.realize_blocks(9, w, iter, 1, r.up_dropped, false);
                assert_eq!(one.is_full(), !r.up_dropped);
                assert_eq!(one, if r.up_dropped { BlockSet::empty(1) } else { BlockSet::full(1) });
                // A dup's block 0 always lands.
                assert!(spec.realize_blocks(9, w, iter, 8, r.up_dropped, true).contains(0));
            }
        }
        // Tail blocks must actually vary under loss.
        let varied = (0..64u64).any(|i| {
            let r = spec.realize(9, 0, i);
            !spec.realize_blocks(9, 0, i, 8, r.up_dropped, false).is_full()
        });
        assert!(varied, "lossy link delivered every block of every reply");
    }

    #[test]
    fn partition_window_kills_all_blocks() {
        let spec = NetSpec { block_size: 1, ..NetSpec::ideal() }.with_partition(&[1], 10, 20);
        assert!(spec.realize_blocks(5, 1, 15, 8, true, false).is_empty());
        assert!(spec.realize_blocks(5, 1, 15, 8, true, true).is_empty());
        assert!(spec.realize_blocks(5, 1, 9, 8, false, false).is_full());
    }

    #[test]
    fn n_blocks_and_admission_policy() {
        let off = NetSpec::ideal();
        assert_eq!(off.n_blocks(1000), 1);
        let spec = NetSpec { block_size: 16, min_block_frac: 0.5, ..NetSpec::ideal() };
        assert_eq!(spec.n_blocks(64), 4);
        assert_eq!(spec.n_blocks(65), 5);
        assert_eq!(spec.n_blocks(8), 1);
        assert_eq!(spec.n_blocks(0), 1);
        // The mask cap.
        assert_eq!(NetSpec { block_size: 1, ..NetSpec::ideal() }.n_blocks(1000), 64);
        use crate::net::block::BlockSet;
        assert!(spec.admits(BlockSet::full(4)));
        assert!(spec.admits(BlockSet::empty(4).with(0).with(1)));
        assert!(!spec.admits(BlockSet::empty(4).with(0)));
        assert!(!spec.admits(BlockSet::empty(4)));
    }

    #[test]
    fn retry_attempts_realize_independently() {
        let spec = NetSpec::lossy(0.4);
        let a0 = spec.realize_attempt(7, 2, 13, 0);
        assert_eq!(a0, spec.realize_attempt(7, 2, 13, 0), "attempt fates must be pure");
        let varies = (1..32u64).any(|k| spec.realize_attempt(7, 2, 13, k) != a0);
        assert!(varies, "retry attempts never varied");
        // Ideal specs short-circuit; partitions kill retries too.
        assert_eq!(NetSpec::ideal().realize_attempt(7, 2, 13, 5), LinkRealization::ideal());
        let part = NetSpec::ideal().with_partition(&[2], 10, 20);
        assert_eq!(part.realize_attempt(7, 2, 13, 5), LinkRealization::partitioned());
    }

    #[test]
    fn edge_rounds_realize_independently() {
        let spec = NetSpec::lossy(0.4);
        let e0 = spec.realize_edge(7, 2, 13, 0);
        assert_eq!(e0, spec.realize_edge(7, 2, 13, 0), "edge fates must be pure");
        let varies = (1..32u64).any(|k| spec.realize_edge(7, 2, 13, k) != e0);
        assert!(varies, "edge rounds never varied");
        // The edge stream is independent of the leaf / retry streams: it
        // must not be forced equal to either for every message key.
        let decoupled = (0..64u64).any(|i| {
            spec.realize_edge(7, 2, i, 0) != spec.realize(7, 2, i)
                || spec.realize_edge(7, 2, i, 0) != spec.realize_attempt(7, 2, i, 0)
        });
        assert!(decoupled, "edge stream coupled to an existing stream");
        assert_eq!(NetSpec::ideal().realize_edge(7, 2, 13, 5), LinkRealization::ideal());
        let part = NetSpec::ideal().with_partition(&[2], 10, 20);
        assert_eq!(part.realize_edge(7, 2, 13, 5), LinkRealization::partitioned());
    }

    #[test]
    fn validate_checks_min_block_frac() {
        let ok = NetSpec { block_size: 8, min_block_frac: 0.5, ..NetSpec::ideal() };
        assert!(ok.validate(4).is_ok());
        let bad = NetSpec { min_block_frac: 1.5, ..NetSpec::ideal() };
        assert!(bad.validate(4).is_err());
        let neg = NetSpec { min_block_frac: -0.1, ..NetSpec::ideal() };
        assert!(neg.validate(4).is_err());
    }

    #[test]
    fn blocking_does_not_make_a_net_non_ideal() {
        // Blocking over an ideal net delivers every block: behaviour (and
        // the ideal fast paths) must be unaffected.
        let spec = NetSpec { block_size: 4, ..NetSpec::ideal() };
        assert!(spec.is_ideal());
    }

    #[test]
    fn validate_checks_ranges_and_windows() {
        let spec = NetSpec::ideal().with_partition(&[3], 5, 10);
        assert!(spec.validate(4).is_ok());
        assert!(spec.validate(3).is_err());
        let empty = NetSpec::ideal().with_partition(&[0], 10, 10);
        assert!(empty.validate(4).is_err());
        let bad_override = NetSpec::ideal().with_override(9, LinkModel::ideal());
        assert!(bad_override.validate(4).is_err());
        assert!(NetSpec::lossy(1.5).validate(4).is_err());
    }
}
