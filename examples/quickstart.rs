//! Quickstart: train kernel ridge regression with the hybrid barrier.
//!
//! Generates a synthetic KRR problem (the paper's eq. 2 workload), runs the
//! Algorithm-1 estimator to pick γ, and trains with the first-γ-of-M hybrid
//! master through the AOT pallas-kernel artifacts (falling back to the
//! pure-rust mirror if artifacts are missing).
//!
//!     cargo run --release --example quickstart

use hybriditer::coordinator::estimator::{estimate_gamma, EstimatorParams};
use hybriditer::coordinator::{LossForm, RunConfig, SyncMode};
use hybriditer::data::{KrrProblem, KrrProblemSpec};
use hybriditer::metrics::csv;
use hybriditer::optim::OptimizerKind;
use hybriditer::runtime::{ArtifactSet, Engine};
use hybriditer::sim;
use hybriditer::straggler::DelayModel;
use hybriditer::cluster::ClusterSpec;
use hybriditer::worker::compute::XlaKrrPool;

fn main() -> anyhow::Result<()> {
    hybriditer::util::logger::init();

    // 1. The problem: N = M·ζ examples of kernel-feature regression.
    let spec = KrrProblemSpec::default_config().with_machines(16);
    println!(
        "problem: N={} examples on M={} machines (zeta={}), l={} features",
        spec.total_examples(),
        spec.machines,
        spec.zeta,
        spec.l
    );
    let problem = KrrProblem::generate(&spec)?;

    // 2. Algorithm 1: how many slaves must the master wait for?
    let params = EstimatorParams { alpha: 0.05, xi: 0.05 };
    let gamma = estimate_gamma(spec.total_examples(), spec.zeta, spec.machines, params)?;
    println!(
        "Algorithm 1: confidence {:.0}%, relative error {:.0}% -> gamma = {gamma} of {}",
        (1.0 - params.alpha) * 100.0,
        params.xi * 100.0,
        spec.machines
    );
    // The distribution-free bound is loose; γ floor of M/2 keeps the demo
    // gradient honest while still abandoning half the cluster.
    let gamma = gamma.max(spec.machines / 2);

    // 3. A straggler-ridden cluster.
    let cluster = ClusterSpec {
        workers: spec.machines,
        base_compute: 0.010,
        delay: DelayModel::LogNormal { mu: -4.0, sigma: 1.0 },
        ..ClusterSpec::default()
    }
    .with_slow_tail(2, 8.0);

    // 4. Train with the hybrid barrier.
    let cfg = RunConfig {
        mode: SyncMode::Hybrid { gamma },
        optimizer: OptimizerKind::sgd(1.0),
        loss_form: LossForm::krr(spec.lambda),
        eval_every: 20,
        ..RunConfig::default()
    }
    .with_iters(300);

    let report = match ArtifactSet::discover() {
        Ok(artifacts) => {
            println!("backend: XLA (AOT pallas kernel artifacts)");
            let engine = Engine::cpu()?;
            let mut pool = XlaKrrPool::new(
                &artifacts,
                &engine,
                &spec.config,
                &problem.shards,
                spec.lambda as f32,
            )?;
            sim::run_virtual(&mut pool, &cluster, &cfg, &problem)?
        }
        Err(e) => {
            println!("backend: native rust mirror ({e})");
            let mut pool = problem.native_pool();
            sim::run_virtual(&mut pool, &cluster, &cfg, &problem)?
        }
    };

    // 5. Report.
    println!("\n{}", report.summary());
    println!(
        "exact optimum reference: loss* = {:.6}, final ‖theta−theta*‖ = {:.4e}",
        problem.loss_star,
        problem.theta_err(&report.theta)
    );
    let path = std::path::Path::new("results/quickstart_loss_curve.csv");
    csv::write_recorder(&report.recorder, path)?;
    println!("loss curve -> {}", path.display());
    Ok(())
}
