//! Flat f32 vector operations used on the coordinator hot path.
//!
//! These run once per iteration on the parameter vector; for KRR `l` is a
//! few hundred, for the LM a few million, so they are written as simple
//! slice loops the compiler auto-vectorizes (verified in the perf pass:
//! `axpy`/`scale_add` compile to packed AVX on this target).

/// `y += a * x` (axpy).
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * *xi;
    }
}

/// `y = x` (copy).
#[inline]
pub fn assign(x: &[f32], y: &mut [f32]) {
    y.copy_from_slice(x);
}

/// Element-wise sum accumulation: `acc += x`.
#[inline]
pub fn add_assign(acc: &mut [f32], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    for (a, b) in acc.iter_mut().zip(x.iter()) {
        *a += *b;
    }
}

/// `x *= a`.
#[inline]
pub fn scale(x: &mut [f32], a: f32) {
    for v in x.iter_mut() {
        *v *= a;
    }
}

/// Dot product (f64 accumulation for stability).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut s = 0.0f64;
    for (a, b) in x.iter().zip(y.iter()) {
        s += *a as f64 * *b as f64;
    }
    s
}

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

/// `||x - y||_2`.
pub fn dist2(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut s = 0.0f64;
    for (a, b) in x.iter().zip(y.iter()) {
        let d = *a as f64 - *b as f64;
        s += d * d;
    }
    s.sqrt()
}

// NOTE: `mean_into`/`weighted_mean_into` used to live here; both were
// redundant with `coordinator::aggregator::aggregate` (Mean and
// ExampleWeighted cover them) and were removed in the perf pass — see
// docs/PERF.md for the invariant that `aggregate` is the single gradient
// combination path.

/// Dense row-major matvec: `out = A x`, A is (m, n).
pub fn matvec(a: &[f32], m: usize, n: usize, x: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), m * n);
    assert_eq!(x.len(), n);
    assert_eq!(out.len(), m);
    for (i, o) in out.iter_mut().enumerate() {
        *o = dot(&a[i * n..(i + 1) * n], x) as f32;
    }
}

/// Transposed matvec: `out = A^T x`, A is (m, n), x is (m), out is (n).
pub fn matvec_t(a: &[f32], m: usize, n: usize, x: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), m * n);
    assert_eq!(x.len(), m);
    assert_eq!(out.len(), n);
    out.fill(0.0);
    for (i, &xi) in x.iter().enumerate() {
        axpy(xi, &a[i * n..(i + 1) * n], out);
    }
}

/// `A^T A` into a dense (n, n) row-major buffer (used by the exact solver).
pub fn gram(a: &[f32], m: usize, n: usize, out: &mut [f64]) {
    assert_eq!(a.len(), m * n);
    assert_eq!(out.len(), n * n);
    out.fill(0.0);
    for row in a.chunks_exact(n) {
        for i in 0..n {
            let ri = row[i] as f64;
            // symmetric: fill upper triangle, mirror later
            for j in i..n {
                out[i * n + j] += ri * row[j] as f64;
            }
        }
    }
    for i in 0..n {
        for j in 0..i {
            out[i * n + j] = out[j * n + i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_and_dot() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![1.0, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0]);
        assert_eq!(dot(&x, &x), 14.0);
    }

    #[test]
    fn norms() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert!((dist2(&[1.0, 1.0], &[4.0, 5.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn matvec_matches_manual() {
        // A = [[1,2],[3,4],[5,6]]
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let x = vec![1.0, -1.0];
        let mut out = vec![0.0; 3];
        matvec(&a, 3, 2, &x, &mut out);
        assert_eq!(out, vec![-1.0, -1.0, -1.0]);

        let xt = vec![1.0, 1.0, 1.0];
        let mut out_t = vec![0.0; 2];
        matvec_t(&a, 3, 2, &xt, &mut out_t);
        assert_eq!(out_t, vec![9.0, 12.0]);
    }

    #[test]
    fn gram_is_ata() {
        let a = vec![1.0, 2.0, 3.0, 4.0]; // [[1,2],[3,4]]
        let mut g = vec![0.0f64; 4];
        gram(&a, 2, 2, &mut g);
        assert_eq!(g, vec![10.0, 14.0, 14.0, 20.0]);
    }
}
