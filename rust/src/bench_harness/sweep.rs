//! Parallel sweep engine: runs independent experiment sweep points
//! concurrently with deterministic result ordering, plus a problem cache
//! so a (γ × drop × seed) grid pays each `KrrProblem::generate` — which
//! includes a Cholesky solve for θ* — exactly once per distinct spec.
//!
//! Every sweep point is an independent `run_virtual` (own pool, own RNG
//! streams seeded from the point), so points are embarrassingly parallel
//! and the table a parallel sweep prints is byte-identical to the serial
//! one.  Wall-clock drops by roughly the core count on the wide sweeps
//! (T1's 10 γ-points, F4's 15 drop×γ cells).
//!
//! Pool size resolution: `--threads N` on the bench command line, else the
//! process default ([`crate::util::pool::default_threads`], settable via
//! the `[bench] threads` config key or `hybriditer train --threads`).

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::data::{KrrProblem, KrrProblemSpec};
use crate::util::pool;

/// Cache of generated problems keyed by their full spec.  Each key holds a
/// `OnceLock` cell, so when a whole pool of sweep points races on the same
/// not-yet-cached spec exactly *one* thread runs `KrrProblem::generate`
/// (the Cholesky solve) while the rest block on the cell — distinct specs
/// still generate concurrently (the map lock is only held to look up the
/// cell, never across generation).
#[derive(Default)]
pub struct ProblemCache {
    map: Mutex<HashMap<String, Arc<OnceLock<Arc<KrrProblem>>>>>,
}

impl ProblemCache {
    pub fn new() -> ProblemCache {
        ProblemCache::default()
    }

    /// The problem for `spec`, generating (and caching) it on first use.
    /// Panics on a degenerate spec — sweep grids are static, so this is a
    /// bench authoring error, not a runtime condition.
    pub fn get(&self, spec: &KrrProblemSpec) -> Arc<KrrProblem> {
        let key = format!("{spec:?}");
        let cell = {
            let mut map = self.map.lock().unwrap();
            Arc::clone(map.entry(key).or_insert_with(|| Arc::new(OnceLock::new())))
        };
        Arc::clone(cell.get_or_init(|| {
            Arc::new(KrrProblem::generate(spec).expect("sweep problem generation"))
        }))
    }

    /// Distinct problems generated so far.
    pub fn len(&self) -> usize {
        self.map
            .lock()
            .unwrap()
            .values()
            .filter(|c| c.get().is_some())
            .count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The bench suite's sweep runner: a scoped worker pool plus a shared
/// [`ProblemCache`].
pub struct SweepEngine {
    threads: usize,
    cache: ProblemCache,
}

impl SweepEngine {
    /// Engine with an explicit pool size (0 = process default).
    pub fn new(threads: usize) -> SweepEngine {
        let threads = if threads == 0 { pool::default_threads() } else { threads };
        SweepEngine {
            threads,
            cache: ProblemCache::new(),
        }
    }

    /// Engine sized from the bench command line (`--threads N`), falling
    /// back to the process default.
    pub fn from_env() -> SweepEngine {
        SweepEngine::new(threads_from_args(std::env::args()).unwrap_or(0))
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn cache(&self) -> &ProblemCache {
        &self.cache
    }

    /// Run `f` over every sweep point concurrently; results come back in
    /// input order (deterministic tables/CSVs).  `f` gets the shared
    /// problem cache and the point.
    pub fn run<P, R, F>(&self, points: &[P], f: F) -> Vec<R>
    where
        P: Sync,
        R: Send,
        F: Fn(&ProblemCache, &P) -> R + Sync,
    {
        pool::scoped_map(self.threads, points, |_, p| f(&self.cache, p))
    }
}

/// Parse `--threads N` / `--threads=N` from an argument stream (benches
/// receive extra args after `cargo bench --bench x -- --threads 4`).
pub fn threads_from_args(args: impl Iterator<Item = String>) -> Option<usize> {
    let mut args = args.peekable();
    while let Some(a) = args.next() {
        if let Some(v) = a.strip_prefix("--threads=") {
            return v.parse().ok();
        }
        if a == "--threads" {
            return args.next().and_then(|v| v.parse().ok());
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> impl Iterator<Item = String> {
        args.iter().map(|s| s.to_string()).collect::<Vec<_>>().into_iter()
    }

    #[test]
    fn threads_arg_parses() {
        assert_eq!(threads_from_args(sv(&["bench", "--threads", "4"])), Some(4));
        assert_eq!(threads_from_args(sv(&["--threads=2"])), Some(2));
        assert_eq!(threads_from_args(sv(&["--bench"])), None);
        assert_eq!(threads_from_args(sv(&["--threads", "x"])), None);
    }

    #[test]
    fn cache_generates_once_per_spec() {
        let cache = ProblemCache::new();
        let spec = KrrProblemSpec {
            config: "test".into(),
            d: 4,
            l: 8,
            zeta: 16,
            machines: 2,
            noise: 0.05,
            lambda: 0.01,
            bandwidth: 1.0,
            eval_rows: 16,
            seed: 9,
        };
        let a = cache.get(&spec);
        let b = cache.get(&spec);
        assert!(Arc::ptr_eq(&a, &b), "same spec must share one instance");
        assert_eq!(cache.len(), 1);
        let other = KrrProblemSpec { seed: 10, ..spec };
        let c = cache.get(&other);
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn sweep_results_keep_input_order() {
        let engine = SweepEngine::new(4);
        let points: Vec<u64> = (0..20).collect();
        let out = engine.run(&points, |_, &p| p * p);
        assert_eq!(out, points.iter().map(|p| p * p).collect::<Vec<_>>());
    }
}
