//! F1 — loss vs (virtual) wall-clock, BSP vs ASYNC vs HYBRID under
//! stragglers: the abstract's headline "dramatically reduce calculation
//! time" figure.
//!
//! Cluster: lognormal delays (σ=1) + two chronically slow nodes (10×).
//! Emits the three loss-vs-time series (CSV for plotting) plus a
//! time-to-target crossover table.  Expected shape: hybrid reaches every
//! loss target first; BSP is latest (tail-latency bound); async sits
//! between (no barrier, but stale gradients slow convergence per update).
//!
//! The three mode runs execute concurrently on the sweep engine
//! (`--threads N` overrides the pool size).

use hybriditer::bench_harness::sweep::SweepEngine;
use hybriditer::bench_harness::{f, Table};
use hybriditer::cluster::ClusterSpec;
use hybriditer::coordinator::{LossForm, RunConfig, SyncMode};
use hybriditer::data::KrrProblemSpec;
use hybriditer::metrics::csv;
use hybriditer::optim::OptimizerKind;
use hybriditer::sim;
use hybriditer::straggler::DelayModel;

fn main() {
    let m = 16;
    let engine = SweepEngine::from_env();
    let spec = KrrProblemSpec::small().with_machines(m);
    let problem = engine.cache().get(&spec);
    let loss_star = problem.loss_star;
    println!("F1: time-to-loss — M={m}, lognormal(σ=1) + 2 slow nodes @10x");
    println!("optimal training loss (exact solver): {loss_star:.6}");
    println!("sweep pool: {} threads\n", engine.threads());

    let iters = 400u64;
    let gamma = m * 3 / 4;
    let points: [(&str, SyncMode, u64, f64); 3] = [
        ("bsp", SyncMode::Bsp, iters, 1.0),
        ("async", SyncMode::Async { damping: 0.0 }, iters * m as u64, 0.35),
        ("hybrid", SyncMode::Hybrid { gamma }, iters, 1.0),
    ];
    let runs = engine.run(&points, |cache, (_, mode, n_iters, eta)| {
        let problem = cache.get(&spec);
        let cluster = ClusterSpec {
            workers: m,
            base_compute: 0.01,
            delay: DelayModel::LogNormal { mu: -4.0, sigma: 1.0 },
            ..ClusterSpec::default()
        }
        .with_slow_tail(2, 10.0);
        let cfg = RunConfig {
            mode: mode.clone(),
            optimizer: OptimizerKind::sgd(*eta),
            loss_form: LossForm::krr(spec.lambda),
            eval_every: 0,
            record_every: 1,
            ..RunConfig::default()
        }
        .with_iters(*n_iters);
        let mut pool = problem.native_pool();
        sim::run_virtual(&mut pool, &cluster, &cfg, &sim::NoEval).unwrap()
    });
    let reports: Vec<(&str, _)> = points
        .iter()
        .map(|(name, ..)| *name)
        .zip(runs)
        .collect();

    // Series CSVs (downsampled print).
    for (name, rep) in &reports {
        let path = std::path::Path::new("results").join(format!("f1_curve_{name}.csv"));
        csv::write_recorder(&rep.recorder, &path).unwrap();
        println!("{name:7} series -> {} ({} rows)", path.display(), rep.recorder.len());
    }

    // Crossover table: first time each mode reaches loss* multiples.
    let mut table = Table::new(
        format!("F1 time to reach loss targets (gamma={gamma})"),
        &["target_loss", "bsp_s", "async_s", "hybrid_s", "hybrid_speedup_vs_bsp"],
    );
    for &mult in &[3.0, 2.0, 1.5, 1.2, 1.1, 1.05] {
        let target = loss_star * mult;
        let times: Vec<Option<f64>> = reports
            .iter()
            .map(|(_, r)| r.recorder.time_to_loss(target))
            .collect();
        let cell = |t: &Option<f64>| t.map(|v| f(v, 3)).unwrap_or_else(|| "-".into());
        let speedup = match (times[0], times[2]) {
            (Some(b), Some(h)) => f(b / h, 2),
            _ => "-".into(),
        };
        table.row(vec![
            format!("{target:.4} ({mult}x)"),
            cell(&times[0]),
            cell(&times[1]),
            cell(&times[2]),
            speedup,
        ]);
    }
    table.print();
    table.save_csv("f1_time_to_loss").unwrap();

    println!();
    for (name, rep) in &reports {
        println!("{}", rep.summary());
        let _ = name;
    }
}
