"""L2: the paper's model — kernel ridge regression fwd/grad — in jax.

Every public function here is an AOT entry point lowered by ``aot.py``.
They are thin jax compositions over the L1 pallas kernels in ``kernels/``:
the pallas calls lower (interpret=True) into the same HLO module, so the
rust runtime executes kernel + glue as one PJRT executable.

Paper mapping:
  * ``worker_grad``     — Algorithm 3 line 2 (one slave's local gradient)
  * ``master_update_*`` — Algorithm 2 line 3 (and momentum/adam variants)
  * ``full_loss``       — the objective of eq. (2), used for convergence
                          tracking and T1/T2 reporting
  * ``features``        — the kernel feature map K[x] (RBF random features)
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import krr_grad as _kg
from .kernels import rbf_features as _rf
from .kernels import updates as _up
from .kernels import ref as _ref


# --- worker side (Algorithm 3) -----------------------------------------


def worker_grad(theta, phi, y, lam):
    """One slave's regularized gradient over its shard (pallas hot path)."""
    return (_kg.krr_grad(theta, phi, y, lam),)


def worker_grad_ref(theta, phi, y, lam):
    """Oracle twin of ``worker_grad`` (pure jnp) for rust cross-checks."""
    return (_ref.krr_grad(theta, phi, y, lam),)


def worker_grad_loss(theta, phi, y, lam):
    """Gradient + shard sum-of-squares in one executable.

    Uses the fused single-sweep pallas kernel: the residual feeds both the
    back-projection and the loss accumulator, halving HBM traffic vs the
    naive grad-kernel + loss-kernel pair (perf pass §Perf L1).
    """
    g, ss = _kg.krr_grad_loss(theta, phi, y, lam)
    return (g, ss)


# --- loss / evaluation ---------------------------------------------------


def full_loss(theta, phi, y, lam):
    """Objective of eq. (2): (1/(2 zeta)) sum r^2 + (lam/2)||theta||^2."""
    ss = _kg.krr_loss_terms(theta, phi, y)
    zeta = phi.shape[0]
    reg = 0.5 * lam * jnp.sum(theta * theta)
    return (0.5 * ss / zeta + reg,)


def predict(theta, phi):
    """Model predictions theta^T K[x] for an evaluation shard."""
    return (phi @ theta,)


# --- feature map (K[x]) --------------------------------------------------


def features(x, w, b):
    """RBF random-Fourier feature map phi = K[x] (pallas kernel)."""
    return (_rf.rbf_features(x, w, b),)


# --- master side (Algorithm 2) -------------------------------------------


def master_update_sgd(theta, gsum, eta_over_gamma):
    """theta - (eta/gamma) * sum_j g_j — Algorithm 2 line 3 (pallas)."""
    return (_up.sgd_update(theta, gsum, eta_over_gamma),)


def master_update_momentum(theta, vel, gbar, eta, mu):
    t, v = _up.momentum_update(theta, vel, gbar, eta, mu)
    return (t, v)


def master_update_adam(theta, m, v, gbar, eta, beta1, beta2, eps, t):
    t2, m2, v2 = _up.adam_update(theta, m, v, gbar, eta, beta1, beta2, eps, t)
    return (t2, m2, v2)
