//! Heavy-ball and Nesterov momentum.

use super::{EtaSchedule, Optimizer};

/// `v ← μv + g;  θ ← θ − η(v)` (heavy ball) or the Nesterov look-ahead
/// variant `θ ← θ − η(g + μv)`.
#[derive(Clone, Debug)]
pub struct Momentum {
    eta: EtaSchedule,
    mu: f64,
    nesterov: bool,
    vel: Vec<f32>,
}

impl Momentum {
    pub fn new(eta: EtaSchedule, mu: f64, nesterov: bool) -> Momentum {
        Momentum {
            eta,
            mu,
            nesterov,
            vel: Vec::new(),
        }
    }
}

impl Optimizer for Momentum {
    fn step(&mut self, theta: &mut [f32], grad: &[f32], iter: u64) {
        if self.vel.len() != theta.len() {
            self.vel = vec![0.0; theta.len()];
        }
        let eta = self.eta.at(iter) as f32;
        let mu = self.mu as f32;
        for i in 0..theta.len() {
            let v = mu * self.vel[i] + grad[i];
            self.vel[i] = v;
            let dir = if self.nesterov { grad[i] + mu * v } else { v };
            theta[i] -= eta * dir;
        }
    }

    fn name(&self) -> &'static str {
        if self.nesterov {
            "nesterov"
        } else {
            "momentum"
        }
    }

    fn reset(&mut self) {
        self.vel.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_mu_equals_sgd() {
        let mut m = Momentum::new(EtaSchedule::constant(0.1), 0.0, false);
        let mut theta = vec![1.0f32];
        m.step(&mut theta, &[1.0], 0);
        assert!((theta[0] - 0.9).abs() < 1e-7);
    }

    #[test]
    fn momentum_accumulates() {
        let mut m = Momentum::new(EtaSchedule::constant(0.1), 0.9, false);
        let mut theta = vec![0.0f32];
        m.step(&mut theta, &[1.0], 0); // v=1, θ=-0.1
        m.step(&mut theta, &[1.0], 1); // v=1.9, θ=-0.29
        assert!((theta[0] + 0.29).abs() < 1e-6);
    }

    #[test]
    fn reset_clears_velocity() {
        let mut m = Momentum::new(EtaSchedule::constant(0.1), 0.9, false);
        let mut theta = vec![0.0f32];
        m.step(&mut theta, &[1.0], 0);
        m.reset();
        let mut theta2 = vec![0.0f32];
        m.step(&mut theta2, &[1.0], 0);
        assert!((theta2[0] + 0.1).abs() < 1e-7);
    }

    #[test]
    fn both_variants_converge() {
        for nesterov in [false, true] {
            let mut m = Momentum::new(EtaSchedule::constant(0.15), 0.9, nesterov);
            let err = crate::optim::test_util::run_quadratic(&mut m, 300);
            assert!(err < 1e-3, "nesterov={nesterov} err={err}");
        }
    }
}
